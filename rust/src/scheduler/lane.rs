//! The unified lane-based stepper — ONE denoise step loop shared by every
//! execution mode (single request, lockstep batch, continuous-batching
//! server).
//!
//! A [`Lane`] is the complete per-request denoise state: latent,
//! conditioning, `CacheState`, cache policy, turbulence RNG, and all the
//! bookkeeping the paper's tables report (block-site counters, token-site
//! ratios, FLOPs, cache bytes, per-lane active wall time). The
//! [`LaneStepper`] advances a *vector* of lanes by one denoise step: per
//! (step, layer) it collects each lane's `BlockAction`, batches the
//! full-token Compute lanes through the compiled B=4 block artifact
//! (chunked, padded when a group is smaller than 4), and routes
//! STR-bucketed, merged, Approx, and Reuse lanes through their per-lane
//! paths. Lanes at *different* step indices coexist in one call — that is
//! what makes continuous batching in `server::worker` possible.
//!
//! `DenoiseEngine` is the batch-of-one driver over this stepper and
//! `BatchEngine` the lockstep driver; neither owns a step/layer loop of
//! its own anymore, so Algorithm 1 (and the Algorithm 2 token-merge
//! extension) exist in exactly one place.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::cache::{build_policy, BlockAction, BlockCtx, CachePolicy, CacheState, StepInfo};
use crate::config::{ApproxMode, FastCacheConfig, C_IN};
use crate::model::{native, DitModel};
use crate::rng::Rng;
use crate::tensor::Tensor;
use crate::tokens::{self, partition};

use super::ddim::DdimSchedule;

/// Turbulence: per-step re-noising of selected token rows — the synthetic
/// stand-in for high-motion content regions (DESIGN.md §2): those tokens
/// keep changing between steps, so a content-aware cache must recompute
/// them while the rest of the latent settles.
#[derive(Clone, Debug)]
pub struct Turbulence {
    pub tokens: Vec<usize>,
    pub amp: f32,
    pub seed: u64,
}

/// One generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: u64,
    pub seed: u64,
    /// Conditioning seed (the "prompt"); drives the CLIP-proxy metric.
    pub cond_seed: u64,
    pub guidance: f32,
    pub steps: usize,
    pub turbulence: Option<Turbulence>,
    /// Optional initial latent (video frames share correlated inits).
    pub init_latent: Option<Tensor>,
    /// Optional SLA deadline in ms from submission. `None` = best-effort.
    /// The sharded server admits deadline-tagged jobs ahead of best-effort
    /// ones at step boundaries and reports per-class deadline-hit rates.
    pub deadline_ms: Option<f64>,
}

impl GenRequest {
    pub fn simple(id: u64, seed: u64, steps: usize) -> GenRequest {
        GenRequest {
            id,
            seed,
            cond_seed: seed ^ 0xC04D,
            guidance: 7.5,
            steps,
            turbulence: None,
            init_latent: None,
            deadline_ms: None,
        }
    }

    /// Tag the request with an SLA deadline (ms from submission).
    pub fn with_deadline(mut self, ms: f64) -> GenRequest {
        self.deadline_ms = Some(ms);
        self
    }
}

/// Per-step execution record (drives Fig. 1/3 style analyses).
#[derive(Clone, Copy, Debug, Default)]
pub struct StepRecord {
    pub step: usize,
    pub computed: usize,
    pub approximated: usize,
    pub reused: usize,
    pub motion_tokens: usize,
    pub n_tokens: usize,
    pub mean_delta: f64,
}

/// Result of one full generation.
#[derive(Debug)]
pub struct GenResult {
    pub id: u64,
    /// Final denoised latent [N, C].
    pub latent: Tensor,
    /// Conditioning vector used (for the CLIP-proxy metric).
    pub cond: Vec<f32>,
    pub records: Vec<StepRecord>,
    /// Per-lane ACTIVE wall time: the time this request actually occupied
    /// the worker, with batched block calls split evenly across the lanes
    /// sharing them. Lanes in a batch no longer all report the whole
    /// group's wall clock.
    pub wall_ms: f64,
    /// Block-site actions over the whole generation.
    pub computed: usize,
    pub approximated: usize,
    pub reused: usize,
    /// Token-site accounting: computed token-sites vs total token-sites
    /// (Tab. 5's static/dynamic ratios are derived from these).
    pub token_sites_computed: u64,
    pub token_sites_total: u64,
    /// FLOPs actually executed vs the NoCache-equivalent total.
    pub flops_done: u64,
    pub flops_full: u64,
    /// FLOPs burnt in padded B=4 batch slots on this lane's behalf
    /// (serving overhead; NOT included in `flops_done`).
    pub flops_padded: u64,
    /// Peak cache-state bytes held for this request.
    pub cache_bytes_peak: usize,
}

impl GenResult {
    pub fn skip_ratio(&self) -> f64 {
        let total = self.computed + self.approximated + self.reused;
        if total == 0 {
            0.0
        } else {
            (self.approximated + self.reused) as f64 / total as f64
        }
    }

    /// Fraction of token-sites NOT computed (the paper's "static ratio").
    pub fn static_ratio(&self) -> f64 {
        if self.token_sites_total == 0 {
            0.0
        } else {
            1.0 - self.token_sites_computed as f64 / self.token_sites_total as f64
        }
    }

    pub fn flops_ratio(&self) -> f64 {
        if self.flops_full == 0 {
            1.0
        } else {
            self.flops_done as f64 / self.flops_full as f64
        }
    }
}

/// Build the conditioning vector for a request: unit-normalized random
/// direction scaled by guidance/7.5 (substitution for CFG text
/// conditioning — see DESIGN.md §2).
pub fn make_cond(d: usize, req: &GenRequest) -> Vec<f32> {
    let mut rng = Rng::new(req.cond_seed);
    let mut c = rng.normal_vec(d, 1.0);
    let norm = c.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-6);
    let scale = (req.guidance / 7.5) * 0.5 / norm * (d as f32).sqrt();
    for v in c.iter_mut() {
        *v *= scale;
    }
    c
}

/// All per-request denoise state, advanced one step at a time by the
/// [`LaneStepper`]. Block-site counters live in `cache.counters`
/// (`CacheCounters`), the canonical per-request tally.
pub struct Lane {
    req: GenRequest,
    cond: Vec<f32>,
    x: Tensor,
    schedule: Arc<DdimSchedule>,
    cache: CacheState,
    policy: Box<dyn CachePolicy>,
    turb_rng: Option<Rng>,
    step: usize,
    records: Vec<StepRecord>,
    token_sites_computed: u64,
    token_sites_total: u64,
    flops_done: u64,
    flops_full: u64,
    flops_padded: u64,
    cache_bytes_peak: usize,
    active: Duration,
    /// Full-compute cost of one denoise step at full tokens (layers ×
    /// block FLOPs) — the unit of the remaining-work prediction below.
    full_step_flops: u64,
}

impl Lane {
    pub fn id(&self) -> u64 {
        self.req.id
    }

    /// The lane's SLA deadline budget (ms from submission), if tagged.
    pub fn deadline_ms(&self) -> Option<f64> {
        self.req.deadline_ms
    }

    /// Predicted FLOPs still ahead of this lane: remaining steps × the
    /// FLOPs this lane has actually *executed* per completed step (full
    /// per-step cost before any step has run). Using executed FLOPs —
    /// not a skip ratio against `flops_full` — captures every source of
    /// per-request compute shift: cache skips (Learning-to-Cache /
    /// SmoothCache-style schedules) AND token reduction (STR buckets,
    /// token merge), where both numerator and denominator of a ratio
    /// would shrink together and cancel the saving. The sharded
    /// dispatcher balances on this estimate, not lane counts.
    pub fn remaining_flops_estimate(&self) -> u64 {
        let rem = self.schedule.len().saturating_sub(self.step) as u64;
        if self.step == 0 {
            return rem * self.full_step_flops;
        }
        let per_step = self.flops_done / self.step as u64;
        rem * per_step.min(self.full_step_flops)
    }

    /// The next step this lane will execute (0-based).
    pub fn step_index(&self) -> usize {
        self.step
    }

    pub fn total_steps(&self) -> usize {
        self.schedule.len()
    }

    pub fn is_done(&self) -> bool {
        self.step >= self.schedule.len()
    }

    pub fn into_result(self) -> GenResult {
        self.finish().0
    }

    /// Consume the lane, returning the result AND the policy (so a caller
    /// that installed a custom policy can keep it across requests).
    pub fn finish(self) -> (GenResult, Box<dyn CachePolicy>) {
        let Lane {
            req,
            cond,
            x,
            cache,
            policy,
            records,
            token_sites_computed,
            token_sites_total,
            flops_done,
            flops_full,
            flops_padded,
            cache_bytes_peak,
            active,
            ..
        } = self;
        let counters = cache.counters;
        (
            GenResult {
                id: req.id,
                latent: x,
                cond,
                records,
                wall_ms: active.as_secs_f64() * 1e3,
                computed: counters.computed,
                approximated: counters.approximated,
                reused: counters.reused,
                token_sites_computed,
                token_sites_total,
                flops_done,
                flops_full,
                flops_padded,
                cache_bytes_peak,
            },
            policy,
        )
    }
}

/// Per-lane transient state of the step currently being executed.
struct StepCtx {
    /// Current hidden state [cur_n, D] (cur_n shrinks when merged).
    h: Tensor,
    /// Conditioning embedding [1, D].
    c: Tensor,
    /// STR bucket index set (None without STR / before the first step).
    motion_idx: Option<Vec<usize>>,
    /// Token-merge context: (merge map, pre-merge Z for residual fusion).
    merge: Option<(tokens::MergeMap, Tensor)>,
    rec: StepRecord,
    delta_sum: f64,
    delta_cnt: usize,
}

/// The unified stepper: one model + one config, advancing any set of lanes
/// (possibly at different step indices) by one denoise step per call.
pub struct LaneStepper<'m> {
    model: &'m DitModel,
    fc: FastCacheConfig,
}

impl<'m> LaneStepper<'m> {
    pub fn new(model: &'m DitModel, fc: FastCacheConfig) -> LaneStepper<'m> {
        LaneStepper { model, fc }
    }

    pub fn model(&self) -> &'m DitModel {
        self.model
    }

    pub fn fc(&self) -> &FastCacheConfig {
        &self.fc
    }

    /// Build a lane with the config's policy.
    pub fn make_lane(&self, req: &GenRequest, schedule: Arc<DdimSchedule>) -> Lane {
        let policy = build_policy(&self.fc, self.model.cfg.layers);
        self.lane_with_policy(req, schedule, policy)
    }

    /// Build a lane around a caller-supplied policy (L2C calibration
    /// flows). The policy is reset before first use.
    pub fn lane_with_policy(
        &self,
        req: &GenRequest,
        schedule: Arc<DdimSchedule>,
        mut policy: Box<dyn CachePolicy>,
    ) -> Lane {
        let cfg = self.model.cfg;
        policy.reset();
        let cond = make_cond(cfg.d, req);
        let x = match &req.init_latent {
            Some(t) => {
                assert_eq!(t.shape(), &[cfg.n_tokens, C_IN]);
                t.clone()
            }
            None => {
                let mut rng = Rng::new(req.seed);
                Tensor::new(rng.normal_vec(cfg.n_tokens * C_IN, 1.0), &[cfg.n_tokens, C_IN])
            }
        };
        Lane {
            turb_rng: req.turbulence.as_ref().map(|t| Rng::new(t.seed)),
            cache: CacheState::new(cfg.layers, cfg.d, self.fc.fit_decay),
            policy,
            cond,
            x,
            schedule,
            req: req.clone(),
            step: 0,
            records: Vec::new(),
            token_sites_computed: 0,
            token_sites_total: 0,
            flops_done: 0,
            flops_full: 0,
            flops_padded: 0,
            cache_bytes_peak: 0,
            active: Duration::ZERO,
            full_step_flops: cfg.full_step_flops(),
        }
    }

    /// Advance every lane by ONE denoise step (its own step index). Per
    /// layer, full-token Compute lanes are batched through the B=4 block
    /// artifact in chunks; everything else runs its per-lane path exactly
    /// as the single-request loop always did.
    pub fn step(&self, lanes: &mut [Lane]) -> Result<()> {
        let cfg = self.model.cfg;
        let (n, d, layers) = (cfg.n_tokens, cfg.d, cfg.layers);
        let nl = lanes.len();
        if nl == 0 {
            return Ok(());
        }
        assert!(
            lanes.iter().all(|l| !l.is_done()),
            "stepping a finished lane — retire lanes before calling step()"
        );

        // ---- Step prologue, per lane: temb + embed + policy + STR. ----
        // Step-aligned lanes share one temb evaluation (in HLO mode each
        // temb is a device dispatch — don't repeat it per lane).
        let mut temb_memo: Vec<(u32, Tensor)> = Vec::new();
        let mut ctxs: Vec<StepCtx> = Vec::with_capacity(nl);
        for lane in lanes.iter_mut() {
            let t0 = Instant::now();
            let step = lane.step;
            let tval = lane.schedule.timesteps[step];

            // Conditioning embedding c = temb(t) + cond.
            let memo_hit = temb_memo.iter().position(|(k, _)| *k == tval.to_bits());
            let mut c = match memo_hit {
                Some(i) => temb_memo[i].1.clone(),
                None => {
                    let t = self.model.temb(&[tval])?; // [1, D]
                    temb_memo.push((tval.to_bits(), t.clone()));
                    t
                }
            };
            for (cv, cd) in c.data_mut().iter_mut().zip(&lane.cond) {
                *cv += cd;
            }

            // Embed latent -> hidden [N, D].
            let xb = lane.x.clone().reshape(&[1, n, C_IN]);
            let h0 = self.model.embed(&xb)?.reshape(&[n, d]);

            // Step-level deltas for the step-granular policies.
            let temb_delta = lane
                .cache
                .prev_temb
                .as_ref()
                .map(|p| native::delta_rel(&c, p))
                .unwrap_or(f64::INFINITY);
            let input_delta = lane
                .cache
                .prev_embed
                .as_ref()
                .map(|p| native::delta_rel(&h0, p))
                .unwrap_or(f64::INFINITY);
            lane.policy.begin_step(&StepInfo {
                step,
                num_steps: lane.schedule.len(),
                temb_delta,
                input_delta,
            });

            // STR: motion/static partition on the embedded state.
            let part = if self.fc.enable_str {
                lane.cache.prev_embed.as_ref().map(|p| partition(&h0, p, self.fc.tau_s))
            } else {
                None
            };
            let motion_idx: Option<Vec<usize>> = part.as_ref().map(tokens::pad_to_bucket);
            let motion_tokens = part.as_ref().map(|p| p.motion.len()).unwrap_or(n);

            lane.cache.store_temb(c.clone());
            lane.cache.store_embed(h0.clone());
            lane.active += t0.elapsed();

            ctxs.push(StepCtx {
                h: h0,
                c,
                motion_idx,
                merge: None,
                rec: StepRecord { step, n_tokens: n, motion_tokens, ..Default::default() },
                delta_sum: 0.0,
                delta_cnt: 0,
            });
        }

        // Token-merge extension (Algorithm 2, S=2 stages): merge at the
        // midpoint, run the rest at the merged bucket, unpool at the end.
        let merge_at = if self.fc.enable_merge { layers / 2 } else { usize::MAX };

        // ---- The block stack, one layer at a time across all lanes. ----
        for l in 0..layers {
            // Per-lane: midpoint merge, delta, and the policy decision.
            let mut actions = Vec::with_capacity(nl);
            for (lane, ctx) in lanes.iter_mut().zip(ctxs.iter_mut()) {
                let t0 = Instant::now();
                if l == merge_at && l > 0 {
                    // Importance = spatial kNN density x temporal saliency.
                    let rho_sp =
                        tokens::knn_density(&ctx.h, self.fc.knn_k.min(ctx.h.shape()[0] - 1));
                    let rho_tm: Vec<f32> = match lane.cache.prev_input(l) {
                        Some(p) if p.shape() == ctx.h.shape() => {
                            tokens::temporal_saliency(&ctx.h, p)
                        }
                        _ => vec![0.0; ctx.h.shape()[0]],
                    };
                    let scores = tokens::importance(&rho_sp, &rho_tm, self.fc.merge_lambda);
                    let (merged, map) = tokens::local_ctm(&ctx.h, &scores, self.fc.merge_target);
                    let z = std::mem::replace(&mut ctx.h, merged); // keep Z for fusion
                    ctx.merge = Some((map, z));
                }

                let cur_n = ctx.h.shape()[0];
                let delta = lane
                    .cache
                    .prev_input(l)
                    .filter(|p| p.shape() == ctx.h.shape())
                    .map(|p| native::delta_rel(&ctx.h, p));
                if let Some(dv) = delta {
                    ctx.delta_sum += dv;
                    ctx.delta_cnt += 1;
                }
                let action = lane.policy.decide(&BlockCtx {
                    layer: l,
                    num_layers: layers,
                    step: ctx.rec.step,
                    delta,
                    nd: cur_n * d,
                });
                lane.flops_full += cfg.block_flops(cur_n);
                lane.token_sites_total += cur_n as u64;
                lane.active += t0.elapsed();
                actions.push(action);
            }

            // Which Compute lanes can share the B=4 block artifact:
            // full-token hidden, not merged, not on the STR bucketed path.
            let batchable: Vec<usize> = (0..nl)
                .filter(|&i| {
                    actions[i] == BlockAction::Compute
                        && ctxs[i].merge.is_none()
                        && ctxs[i].h.shape()[0] == n
                        && !matches!(&ctxs[i].motion_idx,
                                     Some(idx) if idx.len() < n && !idx.is_empty())
                })
                .collect();

            // Batched dispatch when >=2 lanes align; lone lanes fall back
            // to the per-lane B=1 path below.
            let mut outs: Vec<Option<Tensor>> = vec![None; nl];
            if batchable.len() >= 2 {
                const B: usize = 4;
                for group in batchable.chunks(B) {
                    if group.len() == 1 {
                        // Leftover lane of an odd chunking: let the apply
                        // loop's lone-compute path handle it at B=1 (one
                        // code path for all solo computes).
                        continue;
                    }
                    let t0 = Instant::now();
                    let mut hbatch = Vec::with_capacity(B * n * d);
                    let mut cbatch = Vec::with_capacity(B * d);
                    for slot in 0..B {
                        let li = group.get(slot).copied().unwrap_or(group[0]);
                        hbatch.extend_from_slice(ctxs[li].h.data());
                        cbatch.extend_from_slice(ctxs[li].c.data());
                    }
                    let hb = Tensor::new(hbatch, &[B, n, d]);
                    let cb = Tensor::new(cbatch, &[B, d]);
                    let out = self.model.block(l, &hb, &cb)?;
                    for (slot, &li) in group.iter().enumerate() {
                        outs[li] = Some(Tensor::new(
                            out.data()[slot * n * d..(slot + 1) * n * d].to_vec(),
                            &[n, d],
                        ));
                    }
                    // Padded slots re-ran group[0]'s rows: real FLOPs with
                    // no owner — bill them evenly across the group, and
                    // split the group's wall time the same way.
                    let pad_flops = (B - group.len()) as u64 * cfg.block_flops(n);
                    let share = pad_flops / group.len() as u64;
                    let mut rem = pad_flops % group.len() as u64;
                    let dt = t0.elapsed() / group.len() as u32;
                    for &li in group {
                        let extra = if rem > 0 {
                            rem -= 1;
                            1
                        } else {
                            0
                        };
                        lanes[li].flops_padded += share + extra;
                        lanes[li].active += dt;
                    }
                }
            }

            // Apply per-lane results: batched outputs, bucketed STR
            // compute, lone compute, Approx, Reuse.
            for li in 0..nl {
                let lane = &mut lanes[li];
                let ctx = &mut ctxs[li];
                let t0 = Instant::now();
                let cur_n = ctx.h.shape()[0];
                lane.cache.counters.record(actions[li]);
                let h_next = match actions[li] {
                    BlockAction::Compute => {
                        ctx.rec.computed += 1;
                        let out = if let Some(o) = outs[li].take() {
                            // Batched full-token compute.
                            lane.cache.fit_mut(l).update(&ctx.h, &o);
                            lane.flops_done += cfg.block_flops(cur_n);
                            lane.token_sites_computed += cur_n as u64;
                            o
                        } else {
                            match &ctx.motion_idx {
                                Some(idx)
                                    if idx.len() < cur_n
                                        && !idx.is_empty()
                                        && ctx.merge.is_none() =>
                                {
                                    // Bucketed motion-token compute; static
                                    // rows bypass through the affine map.
                                    let nb = idx.len();
                                    let sub = ctx.h.gather_rows(idx);
                                    let sub_b = sub.clone().reshape(&[1, nb, d]);
                                    let out_sub =
                                        self.model.block(l, &sub_b, &ctx.c)?.reshape(&[nb, d]);
                                    lane.cache.fit_mut(l).update(&sub, &out_sub);
                                    let mut out_full = lane.cache.fit(l).apply(&ctx.h);
                                    out_full.scatter_rows(idx, &out_sub);
                                    lane.flops_done += cfg.block_flops(nb)
                                        + cfg.approx_flops(cur_n - nb, false);
                                    lane.token_sites_computed += nb as u64;
                                    out_full
                                }
                                _ => {
                                    // Lone full-token (or merged-size) compute.
                                    let hb = ctx.h.clone().reshape(&[1, cur_n, d]);
                                    let out =
                                        self.model.block(l, &hb, &ctx.c)?.reshape(&[cur_n, d]);
                                    lane.cache.fit_mut(l).update(&ctx.h, &out);
                                    lane.flops_done += cfg.block_flops(cur_n);
                                    lane.token_sites_computed += cur_n as u64;
                                    out
                                }
                            }
                        };
                        let dv = match lane.cache.prev_output(l) {
                            Some(prev_out) if prev_out.shape() == out.shape() => {
                                Some(native::delta_rel(&out, prev_out))
                            }
                            _ => None,
                        };
                        if let Some(dv) = dv {
                            lane.policy.observe_output(l, dv);
                        }
                        out
                    }
                    BlockAction::Approx => {
                        ctx.rec.approximated += 1;
                        lane.flops_done +=
                            cfg.approx_flops(cur_n, self.fc.approx == ApproxMode::FullMatrix);
                        let approx = match self.fc.approx {
                            ApproxMode::FullMatrix => {
                                let (w, b) = lane.cache.fit(l).to_full_matrix();
                                let hb = ctx.h.clone().reshape(&[1, cur_n, d]);
                                self.model
                                    .linear_approx_full(&hb, &w, &b)?
                                    .reshape(&[cur_n, d])
                            }
                            _ => lane.cache.fit(l).apply(&ctx.h),
                        };
                        match lane.cache.prev_output(l) {
                            Some(prev_out)
                                if self.fc.enable_mb && prev_out.shape() == approx.shape() =>
                            {
                                approx.lerp(prev_out, self.fc.gamma, 1.0 - self.fc.gamma)
                            }
                            _ => approx,
                        }
                    }
                    BlockAction::Reuse => {
                        ctx.rec.reused += 1;
                        match lane.cache.prev_output(l) {
                            Some(prev_out) if prev_out.shape() == ctx.h.shape() => {
                                prev_out.clone()
                            }
                            _ => ctx.h.clone(),
                        }
                    }
                };
                // One clone per site instead of two: the pre-block hidden
                // moves into the cache, only the output copy remains.
                let prev = std::mem::replace(&mut ctx.h, h_next);
                lane.cache.store_input(l, prev);
                lane.cache.store_output(l, ctx.h.clone());
                lane.active += t0.elapsed();
            }
        }

        // ---- Step epilogue, per lane: unpool, final layer, DDIM. ----
        for (lane, ctx) in lanes.iter_mut().zip(ctxs.into_iter()) {
            let t0 = Instant::now();
            let StepCtx { mut h, c, merge, mut rec, delta_sum, delta_cnt, .. } = ctx;

            // Unpool + residual fusion if merged (Algorithm 2's MTA phase).
            if let Some((map, z)) = merge {
                let restored = tokens::unpool(&h, &map);
                h = restored.lerp(&z, 1.0, 1.0); // Unpool(H) + Z
            }

            rec.mean_delta = if delta_cnt > 0 { delta_sum / delta_cnt as f64 } else { 0.0 };

            // Final projection + DDIM update.
            let hb = h.reshape(&[1, n, d]);
            let eps = self.model.final_layer(&hb, &c)?.reshape(&[n, C_IN]);
            let sched = Arc::clone(&lane.schedule);
            sched.update(lane.step, lane.x.data_mut(), eps.data());

            // Synthetic motion: re-noise the turbulent token rows.
            if let (Some(t), Some(rng)) = (&lane.req.turbulence, &mut lane.turb_rng) {
                for &i in &t.tokens {
                    for v in lane.x.row_mut(i) {
                        *v += t.amp * rng.normal();
                    }
                }
            }

            lane.records.push(rec);
            lane.cache_bytes_peak = lane.cache_bytes_peak.max(lane.cache.size_bytes());
            lane.step += 1;
            lane.active += t0.elapsed();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PolicyKind, Variant};
    use crate::scheduler::ddim::ScheduleCache;

    #[test]
    fn lane_steps_to_completion() {
        let model = DitModel::native(Variant::S, 7);
        let stepper = LaneStepper::new(&model, FastCacheConfig::with_policy(PolicyKind::NoCache));
        let mut schedules = ScheduleCache::new();
        let mut lane = stepper.make_lane(&GenRequest::simple(1, 3, 5), schedules.get(5));
        assert_eq!(lane.total_steps(), 5);
        while !lane.is_done() {
            let before = lane.step_index();
            stepper.step(std::slice::from_mut(&mut lane)).unwrap();
            assert_eq!(lane.step_index(), before + 1);
        }
        let r = lane.into_result();
        assert_eq!(r.computed, 5 * model.cfg.layers);
        assert_eq!(r.flops_padded, 0, "single lane never pads");
        assert!(r.wall_ms > 0.0);
        assert!(r.latent.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn lanes_at_different_steps_coexist() {
        // Continuous batching's core property: one lane mid-flight, a new
        // lane admitted later, both stepped together, both finish clean.
        let model = DitModel::native(Variant::S, 7);
        let fc = FastCacheConfig::with_policy(PolicyKind::NoCache);
        let stepper = LaneStepper::new(&model, fc.clone());
        let mut schedules = ScheduleCache::new();

        let mut lanes =
            vec![stepper.make_lane(&GenRequest::simple(0, 21, 6), schedules.get(6))];
        stepper.step(&mut lanes).unwrap();
        stepper.step(&mut lanes).unwrap();
        lanes.push(stepper.make_lane(&GenRequest::simple(1, 22, 4), schedules.get(4)));
        for _ in 0..4 {
            stepper.step(&mut lanes).unwrap();
        }
        assert!(lanes.iter().all(|l| l.is_done()));

        // The mid-flight-joined lane matches a solo run exactly.
        let solo = {
            let mut l = stepper.make_lane(&GenRequest::simple(1, 22, 4), schedules.get(4));
            while !l.is_done() {
                stepper.step(std::slice::from_mut(&mut l)).unwrap();
            }
            l.into_result()
        };
        let joined = lanes.pop().unwrap().into_result();
        let md = joined.latent.max_abs_diff(&solo.latent);
        assert!(md < 1e-4, "joined-lane drift: {md}");
    }

    #[test]
    fn remaining_flops_estimate_shrinks_with_progress_and_caching() {
        let model = DitModel::native(Variant::S, 7);
        let mut schedules = ScheduleCache::new();

        // NoCache: before any step the estimate is the full budget; it
        // drains linearly and hits zero at completion.
        let stepper = LaneStepper::new(&model, FastCacheConfig::with_policy(PolicyKind::NoCache));
        let mut lane = stepper.make_lane(&GenRequest::simple(0, 3, 4), schedules.get(4));
        let full = lane.remaining_flops_estimate();
        assert_eq!(full, 4 * model.cfg.full_step_flops());
        stepper.step(std::slice::from_mut(&mut lane)).unwrap();
        assert_eq!(lane.remaining_flops_estimate(), full / 4 * 3);
        while !lane.is_done() {
            stepper.step(std::slice::from_mut(&mut lane)).unwrap();
        }
        assert_eq!(lane.remaining_flops_estimate(), 0);

        // A caching policy that skips work predicts LESS remaining work
        // than NoCache at the same step index.
        let cached =
            LaneStepper::new(&model, FastCacheConfig::with_policy(PolicyKind::StaticCache));
        let mut cl = cached.make_lane(&GenRequest::simple(1, 3, 8), schedules.get(8));
        let mut nl = stepper.make_lane(&GenRequest::simple(1, 3, 8), schedules.get(8));
        for _ in 0..4 {
            cached.step(std::slice::from_mut(&mut cl)).unwrap();
            stepper.step(std::slice::from_mut(&mut nl)).unwrap();
        }
        assert!(
            cl.remaining_flops_estimate() < nl.remaining_flops_estimate(),
            "cache policy should lower the predicted remaining work: {} vs {}",
            cl.remaining_flops_estimate(),
            nl.remaining_flops_estimate()
        );
    }

    #[test]
    fn padded_slots_are_billed() {
        // 3 NoCache lanes => every (step, layer) site batches 3 lanes into
        // the B=4 artifact with one padded slot.
        let model = DitModel::native(Variant::S, 7);
        let stepper = LaneStepper::new(&model, FastCacheConfig::with_policy(PolicyKind::NoCache));
        let mut schedules = ScheduleCache::new();
        let steps = 3;
        let mut lanes: Vec<Lane> = (0..3)
            .map(|i| stepper.make_lane(&GenRequest::simple(i, 50 + i, steps), schedules.get(steps)))
            .collect();
        for _ in 0..steps {
            stepper.step(&mut lanes).unwrap();
        }
        let total_padded: u64 =
            lanes.into_iter().map(|l| l.into_result().flops_padded).sum();
        let expected =
            (steps * model.cfg.layers) as u64 * model.cfg.block_flops(model.cfg.n_tokens);
        assert_eq!(total_padded, expected, "one padded slot per site");
    }
}
