//! DDIM sampling schedule (deterministic, η = 0) with a cosine ᾱ schedule —
//! the 50-step default inference setting of the paper (§5.2), plus the
//! shared [`ScheduleCache`] lanes borrow their schedule from.

use std::sync::Arc;

use crate::store::lru::{ByteSized, LruBytes, LruCounters};

/// Cosine cumulative signal level ᾱ(u), u ∈ [0, 1] (Nichol & Dhariwal).
fn alpha_bar(u: f64) -> f64 {
    let s = 0.008;
    let f = ((u + s) / (1.0 + s) * std::f64::consts::FRAC_PI_2).cos();
    (f * f).clamp(1e-5, 1.0)
}

#[derive(Clone, Debug)]
pub struct DdimSchedule {
    /// Discrete timestep values fed to the model (descending, e.g. 999→0).
    pub timesteps: Vec<f32>,
    /// ᾱ at each sampling step (aligned with `timesteps`).
    pub alphas: Vec<f64>,
    /// ᾱ after the step (the "previous" diffusion time).
    pub alphas_prev: Vec<f64>,
}

impl DdimSchedule {
    pub fn new(steps: usize, train_steps: usize) -> DdimSchedule {
        assert!(steps >= 1);
        let mut timesteps = Vec::with_capacity(steps);
        let mut alphas = Vec::with_capacity(steps);
        let mut alphas_prev = Vec::with_capacity(steps);
        for i in 0..steps {
            // Uniformly strided, descending.
            let frac = 1.0 - i as f64 / steps as f64;
            let frac_next = 1.0 - (i + 1) as f64 / steps as f64;
            timesteps.push((frac * (train_steps as f64 - 1.0)) as f32);
            alphas.push(alpha_bar(frac));
            alphas_prev.push(alpha_bar(frac_next.max(0.0)));
        }
        DdimSchedule { timesteps, alphas, alphas_prev }
    }

    pub fn len(&self) -> usize {
        self.timesteps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.timesteps.is_empty()
    }

    /// One deterministic DDIM update: given x_t and ε̂, produce x_{t−1}.
    /// Operates in place over the latent buffer.
    ///
    /// The x₀ prediction is clipped to ±X0_CLIP (static thresholding, the
    /// standard sampler guard — Imagen-style — against ε̂ mis-scale at high
    /// noise levels; latents are ~unit-variance, so ±3σ is permissive).
    pub fn update(&self, step: usize, x: &mut [f32], eps: &[f32]) {
        const X0_CLIP: f32 = 3.0;
        assert_eq!(x.len(), eps.len());
        let ab = self.alphas[step];
        let ab_prev = self.alphas_prev[step];
        let sq_ab = ab.sqrt() as f32;
        let sq_1m = (1.0 - ab).sqrt() as f32;
        let sq_abp = ab_prev.sqrt() as f32;
        let sq_1mp = (1.0 - ab_prev).sqrt() as f32;
        for (xi, ei) in x.iter_mut().zip(eps) {
            let x0 = ((*xi - sq_1m * ei) / sq_ab).clamp(-X0_CLIP, X0_CLIP);
            *xi = sq_abp * x0 + sq_1mp * ei;
        }
    }
}

impl ByteSized for DdimSchedule {
    fn size_bytes(&self) -> usize {
        self.timesteps.len() * std::mem::size_of::<f32>()
            + (self.alphas.len() + self.alphas_prev.len()) * std::mem::size_of::<f64>()
    }
}

/// Memoized, `Arc`-shared schedules. Engines and the serving worker hand
/// lanes an `Arc<DdimSchedule>` instead of cloning the whole table per
/// request. Bounded: long-lived servers see arbitrarily diverse step
/// counts, so the memo is a byte-budgeted LRU (`store::lru::LruBytes` —
/// the same accounting/eviction primitive the warm-start store shards
/// use) instead of an unbounded map.
pub struct ScheduleCache {
    lru: LruBytes<usize, Arc<DdimSchedule>>,
}

impl Default for ScheduleCache {
    fn default() -> Self {
        ScheduleCache::new()
    }
}

impl ScheduleCache {
    /// Default byte budget: comfortably holds ~50 distinct 100-step
    /// schedules — beyond that, rarely-used step counts are rebuilt on
    /// demand (cheap) instead of held forever.
    pub const DEFAULT_BUDGET_BYTES: usize = 128 * 1024;

    pub fn new() -> ScheduleCache {
        ScheduleCache::with_budget(Self::DEFAULT_BUDGET_BYTES)
    }

    pub fn with_budget(budget_bytes: usize) -> ScheduleCache {
        ScheduleCache { lru: LruBytes::new(budget_bytes) }
    }

    /// Get (or build) the `steps`-step schedule at the 1000-step training
    /// discretization every engine uses. A schedule too large for the
    /// whole budget is still returned — just not retained.
    pub fn get(&mut self, steps: usize) -> Arc<DdimSchedule> {
        if let Some(s) = self.lru.get(&steps) {
            return Arc::clone(s);
        }
        let s = Arc::new(DdimSchedule::new(steps, 1000));
        self.lru.insert(steps, Arc::clone(&s));
        s
    }

    /// Bytes currently retained (always ≤ the budget).
    pub fn used_bytes(&self) -> usize {
        self.lru.used_bytes()
    }

    pub fn budget_bytes(&self) -> usize {
        self.lru.budget()
    }

    pub fn len(&self) -> usize {
        self.lru.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lru.is_empty()
    }

    /// Hit/miss/eviction counters (same shape as the warm store's).
    pub fn counters(&self) -> LruCounters {
        self.lru.counters()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_cache_shares_one_instance() {
        let mut c = ScheduleCache::new();
        let a = c.get(20);
        let b = c.get(20);
        assert!(Arc::ptr_eq(&a, &b), "same steps must share one schedule");
        let other = c.get(10);
        assert!(!Arc::ptr_eq(&a, &other));
        assert_eq!(other.len(), 10);
        assert_eq!(c.counters().hits, 1);
        assert_eq!(c.counters().misses, 2);
    }

    #[test]
    fn schedule_cache_is_byte_bounded_with_lru_drop() {
        // A budget sized for roughly three 50-step schedules: flooding
        // with distinct step counts must stay within budget and keep the
        // recently-used entry alive while dropping cold ones.
        let one = DdimSchedule::new(50, 1000).size_bytes() + crate::store::lru::ENTRY_OVERHEAD;
        let mut c = ScheduleCache::with_budget(3 * one);
        let hot = c.get(50);
        for steps in 51..80 {
            let s = c.get(steps);
            assert_eq!(s.len(), steps);
            // Touch the hot schedule between inserts so it never becomes
            // the LRU victim.
            let again = c.get(50);
            assert!(Arc::ptr_eq(&hot, &again), "hot schedule evicted at steps={steps}");
            assert!(c.used_bytes() <= c.budget_bytes());
        }
        assert!(c.counters().evictions > 0, "flooding never evicted anything");
        assert!(c.len() <= 3);
        // An entry larger than the whole budget is served but not
        // retained — and never breaks the byte bound.
        let mut tiny = ScheduleCache::with_budget(64);
        let big = tiny.get(500);
        assert_eq!(big.len(), 500);
        assert_eq!(tiny.len(), 0);
        assert_eq!(tiny.used_bytes(), 0);
    }

    #[test]
    fn schedule_is_descending_in_time_ascending_in_alpha() {
        let s = DdimSchedule::new(50, 1000);
        assert_eq!(s.len(), 50);
        for w in s.timesteps.windows(2) {
            assert!(w[0] > w[1]);
        }
        for i in 0..s.len() {
            assert!(s.alphas_prev[i] >= s.alphas[i], "step {i}");
            assert!(s.alphas[i] > 0.0 && s.alphas[i] <= 1.0);
        }
        // Near-complete denoising at the end.
        assert!(*s.alphas_prev.last().unwrap() > 0.99);
    }

    #[test]
    fn zero_eps_contracts_toward_x0() {
        // Late step (ᾱ close to 1, no clipping active): with eps=0 the
        // update amplifies by sqrt(ab_prev/ab) >= 1 toward the clean signal.
        let s = DdimSchedule::new(10, 1000);
        let last = s.len() - 1;
        let mut x = vec![0.5f32, -1.0, 0.25];
        let eps = vec![0.0f32; 3];
        let before = x.clone();
        s.update(last, &mut x, &eps);
        for (a, b) in x.iter().zip(&before) {
            assert!(a.abs() >= b.abs() * 0.999, "{a} vs {b}");
            assert_eq!(a.signum(), b.signum());
        }
    }

    #[test]
    fn x0_clipping_bounds_trajectory() {
        // At the highest noise level a zero-eps prediction would explode
        // x0 by 1/sqrt(ab) ~ 300x; the clip keeps the update bounded.
        let s = DdimSchedule::new(50, 1000);
        let mut x = vec![1.0f32, -2.0, 0.5];
        let eps = vec![0.0f32; 3];
        s.update(0, &mut x, &eps);
        for v in &x {
            assert!(v.abs() <= 3.0 + 1e-5, "unbounded update: {v}");
        }
    }

    #[test]
    fn perfect_eps_recovers_x0_at_final_step() {
        // If the model predicts the exact noise, the final update lands on
        // ~x0 (ab_prev ~ 1 at the last step).
        let s = DdimSchedule::new(25, 1000);
        let x0 = vec![0.7f32, -1.1];
        let noise = vec![0.3f32, 0.9];
        let last = s.len() - 1;
        let ab = s.alphas[last];
        let mut x: Vec<f32> = x0
            .iter()
            .zip(&noise)
            .map(|(x0i, ni)| (ab.sqrt() as f32) * x0i + ((1.0 - ab).sqrt() as f32) * ni)
            .collect();
        s.update(last, &mut x, &noise);
        for (xi, x0i) in x.iter().zip(&x0) {
            assert!((xi - x0i).abs() < 0.05, "{xi} vs {x0i}");
        }
    }

    #[test]
    fn single_step_schedule_valid() {
        let s = DdimSchedule::new(1, 1000);
        assert_eq!(s.len(), 1);
        assert!(s.alphas_prev[0] > s.alphas[0]);
    }
}
