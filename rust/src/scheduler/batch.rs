//! Step-aligned dynamic batching: a group of requests advances through the
//! denoise schedule in lockstep; at every block, the requests whose policy
//! says Compute are batched into the B=4 block artifact (padded when the
//! group is smaller), while Approx/Reuse requests take their cheap path
//! individually. This is the vLLM-style static-shape batching adapted to
//! diffusion serving: batching amortizes dispatch and weight traffic for
//! the expensive sites without forcing cache decisions to agree.
//!
//! The batched path serves full-token states (token reduction produces
//! per-request bucket shapes that cannot share a batch; requests wanting
//! STR run the single-request engine instead — see server::worker).

use anyhow::Result;

use crate::cache::{build_policy, BlockAction, BlockCtx, CachePolicy, CacheState, StepInfo};
use crate::config::{ApproxMode, FastCacheConfig, C_IN};
use crate::model::{native, DitModel};
use crate::rng::Rng;
use crate::tensor::Tensor;

use super::ddim::DdimSchedule;
use super::engine::{GenRequest, GenResult, StepRecord};

struct Lane {
    req: GenRequest,
    cond: Vec<f32>,
    x: Tensor,
    cache: CacheState,
    policy: Box<dyn CachePolicy>,
    records: Vec<StepRecord>,
    computed: usize,
    approximated: usize,
    reused: usize,
    token_sites_computed: u64,
    token_sites_total: u64,
    flops_done: u64,
    flops_full: u64,
    cache_bytes_peak: usize,
    turb_rng: Option<Rng>,
}

/// Batched lockstep generation over up to `max_batch` requests.
pub struct BatchEngine<'m> {
    model: &'m DitModel,
    fc: FastCacheConfig,
    pub max_batch: usize,
}

impl<'m> BatchEngine<'m> {
    pub fn new(model: &'m DitModel, fc: FastCacheConfig, max_batch: usize) -> BatchEngine<'m> {
        assert!(max_batch >= 1 && max_batch <= 4);
        BatchEngine { model, fc, max_batch }
    }

    /// Generate a batch of requests in lockstep. All requests must share
    /// the step count (the server's batcher groups by it).
    pub fn generate(&self, reqs: &[GenRequest]) -> Result<Vec<GenResult>> {
        assert!(!reqs.is_empty() && reqs.len() <= self.max_batch);
        let steps = reqs[0].steps;
        assert!(
            reqs.iter().all(|r| r.steps == steps),
            "batch must be step-aligned"
        );
        let cfg = self.model.cfg;
        let (n, d, layers) = (cfg.n_tokens, cfg.d, cfg.layers);
        let schedule = DdimSchedule::new(steps, 1000);

        let mut lanes: Vec<Lane> = reqs
            .iter()
            .map(|req| {
                let eng = super::engine::DenoiseEngine::new(self.model, self.fc.clone());
                let cond = eng.make_cond(req);
                let x = match &req.init_latent {
                    Some(t) => t.clone(),
                    None => {
                        let mut rng = Rng::new(req.seed);
                        Tensor::new(rng.normal_vec(n * C_IN, 1.0), &[n, C_IN])
                    }
                };
                Lane {
                    cond,
                    x,
                    cache: CacheState::new(layers, d, self.fc.fit_decay),
                    policy: build_policy(&self.fc, layers),
                    records: Vec::new(),
                    computed: 0,
                    approximated: 0,
                    reused: 0,
                    token_sites_computed: 0,
                    token_sites_total: 0,
                    flops_done: 0,
                    flops_full: 0,
                    cache_bytes_peak: 0,
                    turb_rng: req.turbulence.as_ref().map(|t| Rng::new(t.seed)),
                    req: req.clone(),
                }
            })
            .collect();

        let t0 = std::time::Instant::now();
        for step in 0..schedule.len() {
            let tval = schedule.timesteps[step];

            // Batched temb: one call at the lane count's artifact (1 or 4).
            let nb = lanes.len();
            let use_b4 = nb > 1;
            let bsz = if use_b4 { 4 } else { 1 };
            let mut ts = vec![tval; bsz];
            ts.truncate(bsz);
            let temb = self.model.temb(&ts)?; // [bsz, D]

            // Per-lane conditioning + embed + step begin.
            let mut hs: Vec<Tensor> = Vec::with_capacity(nb);
            let mut conds: Vec<Tensor> = Vec::with_capacity(nb);
            for (li, lane) in lanes.iter_mut().enumerate() {
                let _ = li;
                let mut c = Tensor::new(temb.data()[..d].to_vec(), &[1, d]);
                for (cv, cd) in c.data_mut().iter_mut().zip(&lane.cond) {
                    *cv += cd;
                }
                let xb = lane.x.clone().reshape(&[1, n, C_IN]);
                let h0 = self.model.embed(&xb)?.reshape(&[n, d]);
                let temb_delta = lane
                    .cache
                    .prev_temb
                    .as_ref()
                    .map(|p| native::delta_rel(&c, p))
                    .unwrap_or(f64::INFINITY);
                let input_delta = lane
                    .cache
                    .prev_embed
                    .as_ref()
                    .map(|p| native::delta_rel(&h0, p))
                    .unwrap_or(f64::INFINITY);
                lane.policy.begin_step(&StepInfo {
                    step,
                    num_steps: schedule.len(),
                    temb_delta,
                    input_delta,
                });
                lane.cache.store_temb(c.clone());
                lane.cache.store_embed(h0.clone());
                lane.records.push(StepRecord {
                    step,
                    n_tokens: n,
                    motion_tokens: n,
                    ..Default::default()
                });
                hs.push(h0);
                conds.push(c);
            }

            for l in 0..layers {
                // Collect decisions.
                let mut actions = Vec::with_capacity(nb);
                for (lane, h) in lanes.iter_mut().zip(&hs) {
                    let delta = lane
                        .cache
                        .prev_input(l)
                        .filter(|p| p.shape() == h.shape())
                        .map(|p| native::delta_rel(h, p));
                    let a = lane.policy.decide(&BlockCtx {
                        layer: l,
                        num_layers: layers,
                        step,
                        delta,
                        nd: n * d,
                    });
                    actions.push(a);
                    lane.flops_full += cfg.block_flops(n);
                    lane.token_sites_total += n as u64;
                }

                let compute_lanes: Vec<usize> = (0..nb)
                    .filter(|&i| actions[i] == BlockAction::Compute)
                    .collect();

                // Batched compute through the B=4 artifact when >=2 lanes
                // need this block; otherwise per-lane B=1.
                let mut outs: Vec<Option<Tensor>> = vec![None; nb];
                if compute_lanes.len() >= 2 {
                    let mut hbatch = Vec::with_capacity(4 * n * d);
                    let mut cbatch = Vec::with_capacity(4 * d);
                    for slot in 0..4 {
                        let li = compute_lanes
                            .get(slot)
                            .copied()
                            .unwrap_or(compute_lanes[0]); // pad with lane 0
                        hbatch.extend_from_slice(hs[li].data());
                        cbatch.extend_from_slice(conds[li].data());
                    }
                    let hb = Tensor::new(hbatch, &[4, n, d]);
                    let cb = Tensor::new(cbatch, &[4, d]);
                    let out = self.model.block(l, &hb, &cb)?;
                    for (slot, &li) in compute_lanes.iter().enumerate() {
                        let sl = Tensor::new(
                            out.data()[slot * n * d..(slot + 1) * n * d].to_vec(),
                            &[n, d],
                        );
                        outs[li] = Some(sl);
                    }
                } else {
                    for &li in &compute_lanes {
                        let hb = hs[li].clone().reshape(&[1, n, d]);
                        let out = self.model.block(l, &hb, &conds[li])?.reshape(&[n, d]);
                        outs[li] = Some(out);
                    }
                }

                // Apply per-lane results.
                for li in 0..nb {
                    let lane = &mut lanes[li];
                    let h = &hs[li];
                    let h_next = match actions[li] {
                        BlockAction::Compute => {
                            lane.computed += 1;
                            lane.records.last_mut().unwrap().computed += 1;
                            lane.flops_done += cfg.block_flops(n);
                            lane.token_sites_computed += n as u64;
                            let out = outs[li].take().unwrap();
                            lane.cache.fit_mut(l).update(h, &out);
                            if let Some(prev_out) = lane.cache.prev_output(l) {
                                if prev_out.shape() == out.shape() {
                                    let dv = native::delta_rel(&out, prev_out);
                                    lane.policy.observe_output(l, dv);
                                }
                            }
                            out
                        }
                        BlockAction::Approx => {
                            lane.approximated += 1;
                            lane.records.last_mut().unwrap().approximated += 1;
                            lane.flops_done +=
                                cfg.approx_flops(n, self.fc.approx == ApproxMode::FullMatrix);
                            let approx = lane.cache.fit(l).apply(h);
                            match lane.cache.prev_output(l) {
                                Some(p) if self.fc.enable_mb && p.shape() == approx.shape() => {
                                    approx.lerp(p, self.fc.gamma, 1.0 - self.fc.gamma)
                                }
                                _ => approx,
                            }
                        }
                        BlockAction::Reuse => {
                            lane.reused += 1;
                            lane.records.last_mut().unwrap().reused += 1;
                            match lane.cache.prev_output(l) {
                                Some(p) if p.shape() == h.shape() => p.clone(),
                                _ => h.clone(),
                            }
                        }
                    };
                    lane.cache.store_input(l, h.clone());
                    lane.cache.store_output(l, h_next.clone());
                    hs[li] = h_next;
                }
            }

            // Final layer + DDIM per lane.
            for (li, lane) in lanes.iter_mut().enumerate() {
                let hb = hs[li].clone().reshape(&[1, n, d]);
                let eps = self.model.final_layer(&hb, &conds[li])?.reshape(&[n, C_IN]);
                schedule.update(step, lane.x.data_mut(), eps.data());
                if let (Some(t), Some(rng)) = (&lane.req.turbulence, &mut lane.turb_rng) {
                    for &i in &t.tokens {
                        for v in lane.x.row_mut(i) {
                            *v += t.amp * rng.normal();
                        }
                    }
                }
                lane.cache_bytes_peak = lane.cache_bytes_peak.max(lane.cache.size_bytes());
            }
        }
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

        Ok(lanes
            .into_iter()
            .map(|lane| GenResult {
                id: lane.req.id,
                latent: lane.x,
                cond: lane.cond,
                records: lane.records,
                wall_ms,
                computed: lane.computed,
                approximated: lane.approximated,
                reused: lane.reused,
                token_sites_computed: lane.token_sites_computed,
                token_sites_total: lane.token_sites_total,
                flops_done: lane.flops_done,
                flops_full: lane.flops_full,
                cache_bytes_peak: lane.cache_bytes_peak,
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PolicyKind, Variant};
    use crate::model::DitModel;
    use crate::scheduler::engine::DenoiseEngine;

    #[test]
    fn batched_matches_single_request_nocache() {
        // Lockstep batching must not change any request's numerics.
        let model = DitModel::native(Variant::S, 3);
        let mut fc = FastCacheConfig::with_policy(PolicyKind::NoCache);
        fc.enable_str = false;
        let reqs: Vec<GenRequest> =
            (0..3).map(|i| GenRequest::simple(i, 40 + i, 4)).collect();

        let be = BatchEngine::new(&model, fc.clone(), 4);
        let batched = be.generate(&reqs).unwrap();

        for (i, req) in reqs.iter().enumerate() {
            let mut eng = DenoiseEngine::new(&model, fc.clone());
            let single = eng.generate(req).unwrap();
            let md = batched[i].latent.max_abs_diff(&single.latent);
            assert!(md < 1e-4, "req {i}: max diff {md}");
        }
    }

    #[test]
    fn batched_fastcache_runs_and_skips() {
        let model = DitModel::native(Variant::S, 3);
        let mut fc = FastCacheConfig::default();
        fc.enable_str = false; // batched path is full-token
        let reqs: Vec<GenRequest> =
            (0..4).map(|i| GenRequest::simple(i, 7 + i, 8)).collect();
        let be = BatchEngine::new(&model, fc, 4);
        let out = be.generate(&reqs).unwrap();
        assert_eq!(out.len(), 4);
        for r in &out {
            assert!(r.computed > 0);
            assert!(r.latent.data().iter().all(|v| v.is_finite()));
        }
        assert!(out.iter().any(|r| r.approximated > 0));
    }

    #[test]
    #[should_panic]
    fn misaligned_steps_rejected() {
        let model = DitModel::native(Variant::S, 3);
        let fc = FastCacheConfig::default();
        let be = BatchEngine::new(&model, fc, 4);
        let mut r1 = GenRequest::simple(0, 1, 4);
        let r2 = GenRequest::simple(1, 2, 8);
        r1.steps = 4;
        let _ = be.generate(&[r1, r2]);
    }
}
