//! `BatchEngine` — lockstep batched generation over the unified lane
//! stepper (`scheduler::lane`). One `Lane` per request, the whole set
//! advanced together by [`LaneStepper::step`], which batches aligned
//! full-token Compute sites through the B=4 block artifact and routes
//! STR-bucketed, merged, FullMatrix-approximated, and Reuse sites through
//! their per-lane paths. There is no separate batched step/layer loop
//! anymore: batched and single-request execution share one code path, so
//! every policy and token-reduction mode batches identically.
//!
//! This type is a convenience wrapper for step-aligned offline batches
//! (evals, benches). The serving path (`server::worker`) drives the
//! stepper directly with continuous batching and admits lanes at
//! different step indices.

use std::sync::Arc;

use anyhow::Result;

use crate::config::FastCacheConfig;
use crate::model::DitModel;

use super::ddim::ScheduleCache;
use super::lane::{GenRequest, GenResult, Lane, LaneStepper};

/// Batched lockstep generation over `max_batch` requests. Compute sites
/// are chunked through the B=4 artifact, so `max_batch` may exceed 4.
pub struct BatchEngine<'m> {
    stepper: LaneStepper<'m>,
    pub max_batch: usize,
    schedules: ScheduleCache,
}

impl<'m> BatchEngine<'m> {
    pub fn new(model: &'m DitModel, fc: FastCacheConfig, max_batch: usize) -> BatchEngine<'m> {
        assert!(max_batch >= 1);
        BatchEngine {
            stepper: LaneStepper::new(model, fc),
            max_batch,
            schedules: ScheduleCache::new(),
        }
    }

    /// Generate a batch of requests in lockstep. All requests must share
    /// the step count — this convenience API finishes every lane
    /// together. (The server has no such restriction: it admits
    /// mixed-step lanes and retires them independently.)
    pub fn generate(&mut self, reqs: &[GenRequest]) -> Result<Vec<GenResult>> {
        assert!(!reqs.is_empty() && reqs.len() <= self.max_batch);
        let steps = reqs[0].steps;
        assert!(reqs.iter().all(|r| r.steps == steps), "batch must be step-aligned");
        let schedule = self.schedules.get(steps);
        let mut lanes: Vec<Lane> = reqs
            .iter()
            .map(|r| self.stepper.make_lane(r, Arc::clone(&schedule)))
            .collect();
        for _ in 0..steps {
            self.stepper.step(&mut lanes)?;
        }
        Ok(lanes.into_iter().map(Lane::into_result).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ApproxMode, PolicyKind, Variant};
    use crate::model::DitModel;
    use crate::scheduler::engine::DenoiseEngine;

    /// Batched results must match per-request single runs bit-for-bit (the
    /// native substrate loops per example, so 1e-4 is generous).
    fn assert_parity(model: &DitModel, fc: &FastCacheConfig, reqs: &[GenRequest]) {
        let mut be = BatchEngine::new(model, fc.clone(), reqs.len().max(1));
        let batched = be.generate(reqs).unwrap();
        for (i, req) in reqs.iter().enumerate() {
            let mut eng = DenoiseEngine::new(model, fc.clone());
            let single = eng.generate(req).unwrap();
            let md = batched[i].latent.max_abs_diff(&single.latent);
            assert!(md < 1e-4, "req {i}: max diff {md}");
            assert_eq!(batched[i].computed, single.computed, "req {i}: site counts drifted");
            assert_eq!(batched[i].approximated, single.approximated, "req {i}");
            assert_eq!(batched[i].reused, single.reused, "req {i}");
        }
    }

    #[test]
    fn batched_matches_single_request_nocache() {
        let model = DitModel::native(Variant::S, 3);
        let mut fc = FastCacheConfig::with_policy(PolicyKind::NoCache);
        fc.enable_str = false;
        let reqs: Vec<GenRequest> =
            (0..3).map(|i| GenRequest::builder(i, 40 + i).steps(4).build().unwrap()).collect();
        assert_parity(&model, &fc, &reqs);
    }

    #[test]
    fn batched_matches_single_request_str() {
        // STR used to force the server onto the slow single-request path;
        // the unified stepper batches the full-token Compute sites and
        // runs bucketed sites per-lane — numerics must not change.
        let model = DitModel::native(Variant::S, 3);
        let fc = FastCacheConfig::with_policy(PolicyKind::FastCache); // STR on
        assert!(fc.enable_str);
        let reqs: Vec<GenRequest> =
            (0..3).map(|i| GenRequest::builder(i, 60 + i).steps(6).build().unwrap()).collect();
        assert_parity(&model, &fc, &reqs);
    }

    #[test]
    fn batched_matches_single_request_merge() {
        let model = DitModel::native(Variant::B, 3);
        let mut fc = FastCacheConfig::with_policy(PolicyKind::FastCache);
        fc.enable_str = false;
        fc.enable_merge = true;
        fc.merge_target = 32;
        let reqs: Vec<GenRequest> =
            (0..3).map(|i| GenRequest::builder(i, 70 + i).steps(4).build().unwrap()).collect();
        assert_parity(&model, &fc, &reqs);
    }

    #[test]
    fn batched_matches_single_request_fullmatrix() {
        let model = DitModel::native(Variant::S, 3);
        let mut fc = FastCacheConfig::with_policy(PolicyKind::FastCache);
        fc.enable_str = false;
        fc.approx = ApproxMode::FullMatrix;
        let reqs: Vec<GenRequest> =
            (0..3).map(|i| GenRequest::builder(i, 80 + i).steps(6).build().unwrap()).collect();
        assert_parity(&model, &fc, &reqs);
    }

    #[test]
    fn batched_fastcache_runs_and_skips() {
        let model = DitModel::native(Variant::S, 3);
        let fc = FastCacheConfig { enable_str: false, ..FastCacheConfig::default() };
        let reqs: Vec<GenRequest> =
            (0..4).map(|i| GenRequest::builder(i, 7 + i).steps(8).build().unwrap()).collect();
        let mut be = BatchEngine::new(&model, fc, 4);
        let out = be.generate(&reqs).unwrap();
        assert_eq!(out.len(), 4);
        for r in &out {
            assert!(r.computed > 0);
            assert!(r.latent.data().iter().all(|v| v.is_finite()));
        }
        assert!(out.iter().any(|r| r.approximated > 0));
    }

    #[test]
    fn per_lane_wall_time_is_individual() {
        // Lanes in one batch no longer all report the group's wall clock:
        // per-lane active times are individually positive and their sum is
        // on the order of (not 4x) the group's end-to-end time.
        let model = DitModel::native(Variant::S, 3);
        let mut fc = FastCacheConfig::with_policy(PolicyKind::NoCache);
        fc.enable_str = false;
        let reqs: Vec<GenRequest> =
            (0..4).map(|i| GenRequest::builder(i, 90 + i).steps(4).build().unwrap()).collect();
        let mut be = BatchEngine::new(&model, fc, 4);
        let t0 = std::time::Instant::now();
        let out = be.generate(&reqs).unwrap();
        let group_ms = t0.elapsed().as_secs_f64() * 1e3;
        let sum_ms: f64 = out.iter().map(|r| r.wall_ms).sum();
        for r in &out {
            assert!(r.wall_ms > 0.0);
            assert!(r.wall_ms <= group_ms, "lane {} reported more than the group", r.id);
        }
        assert!(sum_ms <= group_ms * 1.05, "active times overstate: {sum_ms} vs {group_ms}");
    }

    #[test]
    #[should_panic]
    fn misaligned_steps_rejected() {
        let model = DitModel::native(Variant::S, 3);
        let fc = FastCacheConfig::default();
        let mut be = BatchEngine::new(&model, fc, 4);
        let mut r1 = GenRequest::builder(0, 1).steps(4).build().unwrap();
        let r2 = GenRequest::builder(1, 2).steps(8).build().unwrap();
        r1.steps = 4;
        let _ = be.generate(&[r1, r2]);
    }
}
