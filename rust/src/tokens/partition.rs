//! Spatial-Temporal Token Reduction (paper §3.2, Eq. 1–3): split tokens
//! into motion and static sets by temporal saliency, so static tokens can
//! bypass the whole transformer stack through the learnable linear
//! approximation while motion tokens run bucketed block programs.
//!
//! Saliency is normalized by the mean per-token energy so τ_s is a
//! *relative* threshold (the paper's τ_s ∈ [0.02, 0.05] sweep, Tab. 6).

use crate::config::{token_bucket, TOKEN_BUCKETS};
use crate::model::native;
use crate::tensor::Tensor;

/// The motion/static split of one hidden state.
#[derive(Clone, Debug)]
pub struct Partition {
    /// Indices of motion tokens (ascending).
    pub motion: Vec<usize>,
    /// Indices of static tokens (ascending).
    pub static_: Vec<usize>,
    /// Raw per-token saliency S_t (Eq. 1).
    pub saliency: Vec<f32>,
}

impl Partition {
    pub fn n_tokens(&self) -> usize {
        self.motion.len() + self.static_.len()
    }

    pub fn motion_ratio(&self) -> f64 {
        self.motion.len() as f64 / self.n_tokens().max(1) as f64
    }

    /// The compiled token bucket the motion set runs in (None if no motion
    /// tokens — the whole state is approximated).
    pub fn bucket(&self) -> Option<usize> {
        if self.motion.is_empty() {
            None
        } else {
            Some(token_bucket(self.motion.len()))
        }
    }
}

/// Partition tokens of `x_t` ([N, D]) against `x_prev` by relative
/// saliency threshold `tau_s`.
pub fn partition(x_t: &Tensor, x_prev: &Tensor, tau_s: f64) -> Partition {
    assert_eq!(x_t.shape(), x_prev.shape());
    let n = x_t.shape()[0];
    let sal = native::saliency(x_t, x_prev);

    // Normalizer: mean per-token squared norm of the current state, so the
    // threshold is scale-free. ||x_i - y_i||^2 / mean_i ||x_i||^2 > tau_s.
    let energy: f64 = x_t.data().iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>()
        / n as f64;
    let norm = energy.max(1e-12);

    let mut motion = Vec::new();
    let mut static_ = Vec::new();
    for (i, &s) in sal.iter().enumerate() {
        if (s as f64) / norm > tau_s {
            motion.push(i);
        } else {
            static_.push(i);
        }
    }
    Partition { motion, static_, saliency: sal }
}

/// Pad a motion-token index set up to its bucket size by borrowing the
/// highest-saliency static tokens (keeps the compiled shape exact and
/// spends the padding on the most informative extra tokens).
pub fn pad_to_bucket(p: &Partition) -> Vec<usize> {
    let Some(bucket) = p.bucket() else {
        return Vec::new();
    };
    let mut idx = p.motion.clone();
    if idx.len() < bucket {
        let mut statics: Vec<usize> = p.static_.clone();
        statics.sort_by(|&a, &b| {
            p.saliency[b]
                .partial_cmp(&p.saliency[a])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        for s in statics {
            if idx.len() == bucket {
                break;
            }
            idx.push(s);
        }
        idx.sort_unstable();
    }
    debug_assert!(idx.len() == bucket || idx.len() == p.n_tokens());
    idx
}

/// Largest compiled bucket (the full-token path).
pub fn max_bucket() -> usize {
    *TOKEN_BUCKETS.last().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rnd(seed: u64, shape: &[usize], scale: f32) -> Tensor {
        let mut r = Rng::new(seed);
        Tensor::new(r.normal_vec(shape.iter().product(), scale), shape)
    }

    #[test]
    fn identical_states_all_static() {
        let x = rnd(1, &[64, 16], 1.0);
        let p = partition(&x, &x, 0.02);
        assert!(p.motion.is_empty());
        assert_eq!(p.static_.len(), 64);
        assert_eq!(p.bucket(), None);
    }

    #[test]
    fn moved_tokens_detected() {
        let x_prev = rnd(2, &[64, 16], 1.0);
        let mut x_t = x_prev.clone();
        for &i in &[3usize, 17, 40] {
            for v in x_t.row_mut(i) {
                *v += 2.0;
            }
        }
        let p = partition(&x_t, &x_prev, 0.05);
        assert_eq!(p.motion, vec![3, 17, 40]);
        assert_eq!(p.bucket(), Some(16));
    }

    #[test]
    fn threshold_monotonicity() {
        let x_prev = rnd(3, &[64, 16], 1.0);
        let mut x_t = x_prev.clone();
        let mut r = Rng::new(9);
        for v in x_t.data_mut().iter_mut() {
            *v += 0.3 * r.normal();
        }
        let loose = partition(&x_t, &x_prev, 0.01).motion.len();
        let tight = partition(&x_t, &x_prev, 0.30).motion.len();
        assert!(loose >= tight, "loose={loose} tight={tight}");
    }

    #[test]
    fn partition_covers_all_tokens_disjointly() {
        let x_prev = rnd(4, &[64, 8], 1.0);
        let x_t = rnd(5, &[64, 8], 1.0);
        let p = partition(&x_t, &x_prev, 0.05);
        let mut all: Vec<usize> = p.motion.iter().chain(p.static_.iter()).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn padding_fills_bucket_with_top_salient_statics() {
        let x_prev = rnd(6, &[64, 16], 1.0);
        let mut x_t = x_prev.clone();
        // 3 strong movers + graded static saliency.
        for &i in &[1usize, 2, 3] {
            for v in x_t.row_mut(i) {
                *v += 3.0;
            }
        }
        for v in x_t.row_mut(10) {
            *v += 0.05; // mildly salient static
        }
        let p = partition(&x_t, &x_prev, 0.05);
        let idx = pad_to_bucket(&p);
        assert_eq!(idx.len(), 16);
        assert!(idx.contains(&1) && idx.contains(&2) && idx.contains(&3));
        assert!(idx.contains(&10), "mildly-salient token should be borrowed first");
        // Sorted, unique.
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted, idx);
    }
}
