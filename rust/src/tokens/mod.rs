//! Token-level compression: the Spatial-Temporal Token Reduction module
//! (motion/static partition, §3.2) and the kNN-density token merging
//! module (§3.4 + Appendix D).

pub mod merge;
pub mod partition;

pub use merge::{importance, knn_density, local_ctm, temporal_saliency, unpool, MergeMap};
pub use partition::{pad_to_bucket, partition, Partition};
