//! Spatial-Temporal Token Merging (paper §3.4 + Appendix D):
//! multi-criteria importance S_i = ρ_sp,i · (1 + λ·ρ_tm,i), local
//! clustering-based merge (Local CTM) with importance-weighted averaging
//! (Eq. 13), and the stored-mapping unpool that restores full resolution.

use crate::model::native;
use crate::tensor::Tensor;

/// kNN spatial density ρ_sp (Eq. 10). Self-excluded, exp(−mean kNN d²).
/// Matches the Pallas kernel + ref.py semantics.
pub fn knn_density(x: &Tensor, k: usize) -> Vec<f32> {
    let n = x.shape()[0];
    let d = x.shape()[1];
    assert!(k >= 1 && k < n, "need 1 <= k < n (k={k}, n={n})");
    // Pairwise squared distances (O(N²D); N<=64 at serving sizes).
    let mut rho = Vec::with_capacity(n);
    let data = x.data();
    let mut dists = vec![0.0f32; n];
    for i in 0..n {
        let xi = &data[i * d..(i + 1) * d];
        for j in 0..n {
            if j == i {
                dists[j] = f32::INFINITY;
                continue;
            }
            let xj = &data[j * d..(j + 1) * d];
            let mut acc = 0.0f32;
            for c in 0..d {
                let df = xi[c] - xj[c];
                acc += df * df;
            }
            dists[j] = acc;
        }
        // Partial select of k smallest.
        let mut sel = dists.clone();
        sel.select_nth_unstable_by(k - 1, |a, b| a.partial_cmp(b).unwrap());
        let mean_k: f32 = sel[..k].iter().sum::<f32>() / k as f32;
        rho.push((-mean_k).exp());
    }
    rho
}

/// Temporal saliency ρ_tm (Eq. 11): per-token L2 norm of the state change.
pub fn temporal_saliency(x_t: &Tensor, x_prev: &Tensor) -> Vec<f32> {
    native::saliency(x_t, x_prev).iter().map(|s| s.sqrt()).collect()
}

/// Unified importance score S_i (Eq. 12).
pub fn importance(rho_sp: &[f32], rho_tm: &[f32], lambda: f32) -> Vec<f32> {
    assert_eq!(rho_sp.len(), rho_tm.len());
    rho_sp
        .iter()
        .zip(rho_tm)
        .map(|(sp, tm)| sp * (1.0 + lambda * tm))
        .collect()
}

/// The merge mapping M: for each original token, the cluster it joined.
#[derive(Clone, Debug)]
pub struct MergeMap {
    pub assignment: Vec<usize>,
    pub num_clusters: usize,
}

/// Local clustering-based token merge: greedy importance-ranked seeding,
/// then nearest-seed assignment — merged token = importance-weighted mean
/// of its cluster (Eq. 13). Returns ([num_clusters, D], M).
pub fn local_ctm(x: &Tensor, scores: &[f32], target: usize) -> (Tensor, MergeMap) {
    let n = x.shape()[0];
    let d = x.shape()[1];
    assert_eq!(scores.len(), n);
    let target = target.clamp(1, n);

    // Seeds: greedy score-weighted farthest-point sampling ("local"
    // clustering: the first seed is the most important token; each next
    // seed maximizes importance × distance-to-selected, so dense distinct
    // regions each get a representative).
    let data = x.data();
    let sqdist = |a: usize, b: usize| -> f32 {
        let xa = &data[a * d..(a + 1) * d];
        let xb = &data[b * d..(b + 1) * d];
        let mut acc = 0.0f32;
        for c in 0..d {
            let df = xa[c] - xb[c];
            acc += df * df;
        }
        acc
    };
    let mut seeds: Vec<usize> = Vec::with_capacity(target);
    let first = (0..n)
        .max_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap_or(std::cmp::Ordering::Equal))
        .unwrap();
    seeds.push(first);
    let mut min_d: Vec<f32> = (0..n).map(|i| sqdist(i, first)).collect();
    while seeds.len() < target {
        let next = (0..n)
            .filter(|i| !seeds.contains(i))
            .max_by(|&a, &b| {
                let va = scores[a].max(1e-12) * (min_d[a] + 1e-12);
                let vb = scores[b].max(1e-12) * (min_d[b] + 1e-12);
                va.partial_cmp(&vb).unwrap_or(std::cmp::Ordering::Equal)
            })
            .unwrap();
        seeds.push(next);
        for i in 0..n {
            min_d[i] = min_d[i].min(sqdist(i, next));
        }
    }
    let seeds = &seeds[..];

    // Assign every token to its nearest seed.
    let mut assignment = vec![0usize; n];
    for i in 0..n {
        let xi = &data[i * d..(i + 1) * d];
        let mut best = 0usize;
        let mut best_d = f32::INFINITY;
        for (ci, &s) in seeds.iter().enumerate() {
            let xs = &data[s * d..(s + 1) * d];
            let mut acc = 0.0f32;
            for c in 0..d {
                let df = xi[c] - xs[c];
                acc += df * df;
            }
            if acc < best_d {
                best_d = acc;
                best = ci;
            }
        }
        assignment[i] = best;
    }

    // Importance-weighted cluster means (Eq. 13).
    let mut merged = vec![0.0f32; target * d];
    let mut wsum = vec![0.0f32; target];
    for i in 0..n {
        let c = assignment[i];
        let w = scores[i].max(1e-12);
        wsum[c] += w;
        let xi = &data[i * d..(i + 1) * d];
        let row = &mut merged[c * d..(c + 1) * d];
        for j in 0..d {
            row[j] += w * xi[j];
        }
    }
    for c in 0..target {
        let w = wsum[c].max(1e-12);
        for v in &mut merged[c * d..(c + 1) * d] {
            *v /= w;
        }
    }

    (
        Tensor::new(merged, &[target, d]),
        MergeMap { assignment, num_clusters: target },
    )
}

/// Unpool: scatter merged rows back to original resolution via the stored
/// mapping (each token receives its cluster representative).
pub fn unpool(merged: &Tensor, map: &MergeMap) -> Tensor {
    let d = merged.shape()[1];
    assert_eq!(merged.shape()[0], map.num_clusters);
    let n = map.assignment.len();
    let mut out = Vec::with_capacity(n * d);
    for &c in &map.assignment {
        out.extend_from_slice(&merged.data()[c * d..(c + 1) * d]);
    }
    Tensor::new(out, &[n, d])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rnd(seed: u64, shape: &[usize], scale: f32) -> Tensor {
        let mut r = Rng::new(seed);
        Tensor::new(r.normal_vec(shape.iter().product(), scale), shape)
    }

    #[test]
    fn knn_density_matches_python_semantics() {
        // Cluster + outlier, mirrors test_knn_density_cluster_center_is_densest.
        let mut x = rnd(1, &[16, 8], 0.01);
        for v in x.row_mut(0) {
            *v += 50.0;
        }
        let rho = knn_density(&x, 3);
        let min_i = rho
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(min_i, 0);
        assert!(rho.iter().all(|&r| (0.0..=1.0 + 1e-6).contains(&r)));
    }

    #[test]
    fn importance_scales_with_motion() {
        let sp = vec![0.5, 0.5];
        let tm = vec![0.0, 2.0];
        let s = importance(&sp, &tm, 0.5);
        assert!((s[0] - 0.5).abs() < 1e-6);
        assert!((s[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn ctm_reduces_to_target_and_unpool_restores_shape() {
        let x = rnd(2, &[64, 8], 1.0);
        let scores = vec![1.0f32; 64];
        let (merged, map) = local_ctm(&x, &scores, 16);
        assert_eq!(merged.shape(), &[16, 8]);
        assert_eq!(map.assignment.len(), 64);
        assert!(map.assignment.iter().all(|&c| c < 16));
        let restored = unpool(&merged, &map);
        assert_eq!(restored.shape(), &[64, 8]);
    }

    #[test]
    fn identical_tokens_merge_losslessly() {
        // All tokens identical -> any clustering reproduces them exactly.
        let x = Tensor::full(&[32, 4], 1.5);
        let scores = vec![1.0f32; 32];
        let (merged, map) = local_ctm(&x, &scores, 8);
        let restored = unpool(&merged, &map);
        assert!(restored.max_abs_diff(&x) < 1e-6);
    }

    #[test]
    fn two_well_separated_clusters_stay_separated() {
        let mut x = rnd(3, &[16, 4], 0.01);
        for i in 8..16 {
            for v in x.row_mut(i) {
                *v += 10.0;
            }
        }
        let scores = vec![1.0f32; 16];
        let (_, map) = local_ctm(&x, &scores, 2);
        // Tokens 0-7 in one cluster, 8-15 in the other.
        let c0 = map.assignment[0];
        assert!(map.assignment[..8].iter().all(|&c| c == c0));
        assert!(map.assignment[8..].iter().all(|&c| c != c0));
    }

    #[test]
    fn target_clamped() {
        let x = rnd(4, &[8, 4], 1.0);
        let scores = vec![1.0f32; 8];
        let (merged, _) = local_ctm(&x, &scores, 100);
        assert_eq!(merged.shape()[0], 8);
    }
}
