//! The serving worker: continuous batching over the unified lane stepper.
//!
//! The old design drained the queue into step-aligned lockstep groups and
//! fell back to slow single-request mode whenever STR or token merge was
//! enabled (`can_batch`). That gate is gone: every config runs through
//! `LaneStepper::step`, which batches whatever aligns (full-token Compute
//! sites through the B=4 artifact) and runs the rest per-lane. Lanes at
//! different step indices coexist in one active set; finished lanes
//! retire and queued jobs are admitted at step boundaries, so the worker
//! never drains before taking new work.

use std::sync::mpsc::{self, Receiver, SyncSender, TryRecvError, TrySendError};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::config::{FastCacheConfig, ServerConfig};
use crate::metrics::LatencyHistogram;
use crate::model::DitModel;
use crate::scheduler::{GenRequest, Lane, LaneStepper, ScheduleCache};

use super::queue::{GenResponse, Job, SubmitError};

/// Final report when the server shuts down.
#[derive(Debug)]
pub struct ServerReport {
    pub completed: u64,
    pub e2e: LatencyHistogram,
    /// Admission latency: submit → lane admitted into the active set (ms).
    pub admission_wait: LatencyHistogram,
    pub wall_s: f64,
    /// Unified-stepper invocations; each advances every active lane by
    /// one denoise step.
    pub step_calls: u64,
    /// Occupancy integral: Σ over step calls of the active-lane count.
    pub lane_steps: u64,
    /// FLOPs burnt in padded B=4 batch slots across all completed lanes
    /// (batch-shape overhead that used to be invisible).
    pub padded_flops: u64,
}

impl ServerReport {
    fn new() -> ServerReport {
        ServerReport {
            completed: 0,
            e2e: LatencyHistogram::new(),
            admission_wait: LatencyHistogram::new(),
            wall_s: 0.0,
            step_calls: 0,
            lane_steps: 0,
            padded_flops: 0,
        }
    }

    pub fn throughput_rps(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.completed as f64 / self.wall_s
        }
    }

    /// Mean number of lanes advancing together per step call — the
    /// continuous-batching occupancy. > 1 means batching happened.
    pub fn mean_batch_size(&self) -> f64 {
        if self.step_calls == 0 {
            0.0
        } else {
            self.lane_steps as f64 / self.step_calls as f64
        }
    }

    /// Alias with the serving-literature name.
    pub fn occupancy(&self) -> f64 {
        self.mean_batch_size()
    }
}

/// A running server instance.
pub struct Server {
    tx: Option<SyncSender<Job>>,
    handle: Option<JoinHandle<ServerReport>>,
}

impl Server {
    /// Start the worker. `model_factory` runs ON the worker thread (PJRT
    /// clients are not shared across threads).
    pub fn start<F>(scfg: ServerConfig, fc: FastCacheConfig, model_factory: F) -> Server
    where
        F: FnOnce() -> Result<DitModel> + Send + 'static,
    {
        let (tx, rx) = mpsc::sync_channel::<Job>(scfg.queue_depth);
        let handle = std::thread::spawn(move || worker_loop(scfg, fc, model_factory, rx));
        Server { tx: Some(tx), handle: Some(handle) }
    }

    /// Submit a request; returns the response channel or backpressure.
    pub fn submit(&self, req: GenRequest) -> Result<mpsc::Receiver<GenResponse>, SubmitError> {
        let (rtx, rrx) = mpsc::channel();
        let job = Job { req, resp: rtx, submitted: Instant::now() };
        match self.tx.as_ref().ok_or(SubmitError::Closed)?.try_send(job) {
            Ok(()) => Ok(rrx),
            Err(TrySendError::Full(_)) => Err(SubmitError::QueueFull),
            Err(TrySendError::Disconnected(_)) => Err(SubmitError::Closed),
        }
    }

    /// Submit, sleeping through backpressure until the queue accepts the
    /// request. Only fails when the server is shutting down.
    pub fn submit_blocking(
        &self,
        req: &GenRequest,
    ) -> Result<mpsc::Receiver<GenResponse>, SubmitError> {
        loop {
            match self.submit(req.clone()) {
                Ok(rx) => return Ok(rx),
                Err(SubmitError::QueueFull) => {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Close the queue and wait for the worker to drain.
    pub fn shutdown(mut self) -> ServerReport {
        drop(self.tx.take());
        self.handle.take().expect("not yet joined").join().expect("worker panicked")
    }
}

/// A lane's serving-side envelope, parallel to the lane vector.
struct Inflight {
    job: Job,
    admitted: Instant,
}

fn worker_loop<F>(
    scfg: ServerConfig,
    fc: FastCacheConfig,
    model_factory: F,
    rx: Receiver<Job>,
) -> ServerReport
where
    F: FnOnce() -> Result<DitModel>,
{
    let model = model_factory().expect("model load failed");
    let stepper = LaneStepper::new(&model, fc);
    let mut schedules = ScheduleCache::new();
    let mut report = ServerReport::new();
    // Guard against unvalidated configs: max_batch = 0 must degrade to
    // solo serving, not livelock the admission loop.
    let max_batch = scfg.max_batch.max(1);
    let t0 = Instant::now();

    let mut lanes: Vec<Lane> = Vec::new();
    let mut inflight: Vec<Inflight> = Vec::new();
    let mut closed = false;

    loop {
        // Admission, at the step boundary: fill free lane slots. Block
        // only when idle; otherwise take whatever is already queued.
        while !closed && lanes.len() < max_batch {
            let job = if lanes.is_empty() {
                match rx.recv() {
                    Ok(j) => j,
                    Err(_) => {
                        closed = true;
                        break;
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(j) => j,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        closed = true;
                        break;
                    }
                }
            };
            // One admission instant, used for both the report histogram
            // and the per-response queued_ms — they must agree.
            let admitted = Instant::now();
            report
                .admission_wait
                .record(admitted.duration_since(job.submitted).as_secs_f64() * 1e3);
            lanes.push(stepper.make_lane(&job.req, schedules.get(job.req.steps)));
            inflight.push(Inflight { job, admitted });
        }
        if lanes.is_empty() {
            if closed {
                break;
            }
            continue;
        }

        // One denoise step across the whole active set (lanes may sit at
        // different step indices — the stepper handles that).
        report.step_calls += 1;
        report.lane_steps += lanes.len() as u64;
        stepper.step(&mut lanes).expect("denoise step failed");

        // Retire finished lanes; their slots free up for the next
        // admission round.
        let mut i = 0;
        while i < lanes.len() {
            if !lanes[i].is_done() {
                i += 1;
                continue;
            }
            let lane = lanes.swap_remove(i);
            let fl = inflight.swap_remove(i);
            let result = lane.into_result();
            report.padded_flops += result.flops_padded;
            let e2e = fl.job.submitted.elapsed().as_secs_f64() * 1e3;
            let queued_ms = fl.admitted.duration_since(fl.job.submitted).as_secs_f64() * 1e3;
            report.e2e.record(e2e);
            report.completed += 1;
            let _ = fl.job.resp.send(GenResponse { result, queued_ms, e2e_ms: e2e });
        }
    }

    report.wall_s = t0.elapsed().as_secs_f64();
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PolicyKind, Variant};
    use crate::scheduler::GenRequest;

    fn test_server(policy: PolicyKind, max_batch: usize, queue_depth: usize) -> Server {
        let mut scfg = ServerConfig::default();
        scfg.max_batch = max_batch;
        scfg.queue_depth = queue_depth;
        let mut fc = FastCacheConfig::with_policy(policy);
        fc.enable_str = false;
        Server::start(scfg, fc, || Ok(DitModel::native(Variant::S, 1)))
    }

    #[test]
    fn serves_requests_end_to_end() {
        let server = test_server(PolicyKind::FastCache, 4, 16);
        let mut rxs = Vec::new();
        for i in 0..6 {
            rxs.push(server.submit(GenRequest::simple(i, 100 + i, 4)).unwrap());
        }
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.result.latent.data().iter().all(|v| v.is_finite()));
            assert!(resp.e2e_ms >= resp.queued_ms);
        }
        let report = server.shutdown();
        assert_eq!(report.completed, 6);
        assert!(report.throughput_rps() > 0.0);
        assert_eq!(report.admission_wait.count(), 6);
    }

    #[test]
    fn backpressure_when_queue_full() {
        // Tiny queue; flood it faster than the worker drains.
        let server = test_server(PolicyKind::NoCache, 1, 1);
        let mut saw_full = false;
        let mut rxs = Vec::new();
        for i in 0..50 {
            match server.submit(GenRequest::simple(i, i, 8)) {
                Ok(rx) => rxs.push(rx),
                Err(SubmitError::QueueFull) => {
                    saw_full = true;
                    break;
                }
                Err(e) => panic!("unexpected: {e}"),
            }
        }
        assert!(saw_full, "bounded queue never pushed back");
        for rx in rxs {
            let _ = rx.recv();
        }
        server.shutdown();
    }

    #[test]
    fn submit_after_shutdown_fails() {
        let server = test_server(PolicyKind::NoCache, 1, 4);
        let rx = server.submit(GenRequest::simple(0, 0, 2)).unwrap();
        let _ = rx.recv();
        // Shutdown consumes the server; a clone of tx would be Closed.
        let report = server.shutdown();
        assert_eq!(report.completed, 1);
    }

    #[test]
    fn batches_form_under_load() {
        let server = test_server(PolicyKind::FastCache, 4, 32);
        let mut rxs = Vec::new();
        for i in 0..12 {
            rxs.push(server.submit(GenRequest::simple(i, 7 + i, 4)).unwrap());
        }
        for rx in rxs {
            let _ = rx.recv().unwrap();
        }
        let report = server.shutdown();
        assert_eq!(report.completed, 12);
        assert!(
            report.mean_batch_size() > 1.0,
            "no batching happened: {}",
            report.mean_batch_size()
        );
    }

    #[test]
    fn str_enabled_configs_batch() {
        // The whole point of the unified stepper: STR (and every other
        // token-reduction mode) no longer forces single-request serving.
        let mut scfg = ServerConfig::default();
        scfg.max_batch = 4;
        scfg.queue_depth = 32;
        let fc = FastCacheConfig::with_policy(PolicyKind::FastCache);
        assert!(fc.enable_str, "FastCache default must enable STR");
        let server = Server::start(scfg, fc, || Ok(DitModel::native(Variant::S, 1)));
        let mut rxs = Vec::new();
        for i in 0..12 {
            rxs.push(server.submit(GenRequest::simple(i, 31 + i, 6)).unwrap());
        }
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.result.latent.data().iter().all(|v| v.is_finite()));
        }
        let report = server.shutdown();
        assert_eq!(report.completed, 12);
        assert!(
            report.mean_batch_size() > 1.0,
            "STR config did not batch: occupancy {}",
            report.mean_batch_size()
        );
    }

    #[test]
    fn mixed_step_requests_coexist() {
        // Continuous batching admits lanes with different step counts into
        // one active set — no step-alignment grouping anymore.
        let server = test_server(PolicyKind::FastCache, 4, 32);
        let mut rxs = Vec::new();
        for i in 0..4u64 {
            rxs.push((4usize, server.submit(GenRequest::simple(i, 11 + i, 4)).unwrap()));
            rxs.push((8usize, server.submit(GenRequest::simple(10 + i, 17 + i, 8)).unwrap()));
        }
        for (steps, rx) in rxs {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.result.records.len(), steps);
        }
        let report = server.shutdown();
        assert_eq!(report.completed, 8);
        assert!(report.mean_batch_size() > 1.0);
    }
}
