//! The serving worker: drains the bounded queue, forms step-aligned
//! batches, and runs them through the batch engine (full-token mode) or
//! the single-request engine (token-reduction mode, whose bucketed shapes
//! cannot share a batch).

use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::config::{FastCacheConfig, ServerConfig};
use crate::metrics::LatencyHistogram;
use crate::model::DitModel;
use crate::scheduler::{BatchEngine, DenoiseEngine, GenRequest};

use super::queue::{GenResponse, Job, SubmitError};

/// Final report when the server shuts down.
#[derive(Debug)]
pub struct ServerReport {
    pub completed: u64,
    pub e2e: LatencyHistogram,
    pub queue_wait: LatencyHistogram,
    pub wall_s: f64,
    pub batches: u64,
    pub batched_requests: u64,
}

impl ServerReport {
    pub fn throughput_rps(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.completed as f64 / self.wall_s
        }
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batches as f64
        }
    }
}

/// A running server instance.
pub struct Server {
    tx: Option<SyncSender<Job>>,
    handle: Option<JoinHandle<ServerReport>>,
}

impl Server {
    /// Start the worker. `model_factory` runs ON the worker thread (PJRT
    /// clients are not shared across threads).
    pub fn start<F>(scfg: ServerConfig, fc: FastCacheConfig, model_factory: F) -> Server
    where
        F: FnOnce() -> Result<DitModel> + Send + 'static,
    {
        let (tx, rx) = mpsc::sync_channel::<Job>(scfg.queue_depth);
        let handle = std::thread::spawn(move || worker_loop(scfg, fc, model_factory, rx));
        Server { tx: Some(tx), handle: Some(handle) }
    }

    /// Submit a request; returns the response channel or backpressure.
    pub fn submit(&self, req: GenRequest) -> Result<mpsc::Receiver<GenResponse>, SubmitError> {
        let (rtx, rrx) = mpsc::channel();
        let job = Job { req, resp: rtx, submitted: Instant::now() };
        match self.tx.as_ref().ok_or(SubmitError::Closed)?.try_send(job) {
            Ok(()) => Ok(rrx),
            Err(TrySendError::Full(_)) => Err(SubmitError::QueueFull),
            Err(TrySendError::Disconnected(_)) => Err(SubmitError::Closed),
        }
    }

    /// Close the queue and wait for the worker to drain.
    pub fn shutdown(mut self) -> ServerReport {
        drop(self.tx.take());
        self.handle.take().expect("not yet joined").join().expect("worker panicked")
    }
}

fn worker_loop<F>(
    scfg: ServerConfig,
    fc: FastCacheConfig,
    model_factory: F,
    rx: Receiver<Job>,
) -> ServerReport
where
    F: FnOnce() -> Result<DitModel>,
{
    let model = model_factory().expect("model load failed");
    let mut report = ServerReport {
        completed: 0,
        e2e: LatencyHistogram::new(),
        queue_wait: LatencyHistogram::new(),
        wall_s: 0.0,
        batches: 0,
        batched_requests: 0,
    };
    let t0 = Instant::now();

    // STR produces per-request bucket shapes; batching needs uniform
    // full-token shapes.
    let can_batch = !fc.enable_str && !fc.enable_merge && scfg.max_batch > 1;

    loop {
        // Blocking wait for the first job; drain compatible ones behind it.
        let first = match rx.recv() {
            Ok(j) => j,
            Err(_) => break, // queue closed and empty
        };
        let mut group = vec![first];
        if can_batch {
            while group.len() < scfg.max_batch {
                match rx.try_recv() {
                    Ok(j) if j.req.steps == group[0].req.steps => group.push(j),
                    Ok(j) => {
                        // Step-misaligned: serve it solo right after.
                        process_group(&model, &fc, vec![j], &mut report, false);
                        continue;
                    }
                    Err(_) => break,
                }
            }
        }
        let batched = can_batch && group.len() > 1;
        process_group(&model, &fc, group, &mut report, batched);
    }

    report.wall_s = t0.elapsed().as_secs_f64();
    report
}

fn process_group(
    model: &DitModel,
    fc: &FastCacheConfig,
    group: Vec<Job>,
    report: &mut ServerReport,
    batched: bool,
) {
    let picked = Instant::now();
    for j in &group {
        report
            .queue_wait
            .record(picked.duration_since(j.submitted).as_secs_f64() * 1e3);
    }
    report.batches += 1;
    report.batched_requests += group.len() as u64;

    if batched {
        let reqs: Vec<GenRequest> = group.iter().map(|j| j.req.clone()).collect();
        let be = BatchEngine::new(model, fc.clone(), group.len().max(1));
        match be.generate(&reqs) {
            Ok(results) => {
                for (job, result) in group.into_iter().zip(results) {
                    let e2e = job.submitted.elapsed().as_secs_f64() * 1e3;
                    report.e2e.record(e2e);
                    report.completed += 1;
                    let queued_ms = picked.duration_since(job.submitted).as_secs_f64() * 1e3;
                    let _ = job.resp.send(GenResponse { result, queued_ms, e2e_ms: e2e });
                }
            }
            Err(e) => panic!("batch generation failed: {e:#}"),
        }
    } else {
        for job in group {
            let mut eng = DenoiseEngine::new(model, fc.clone());
            match eng.generate(&job.req) {
                Ok(result) => {
                    let e2e = job.submitted.elapsed().as_secs_f64() * 1e3;
                    report.e2e.record(e2e);
                    report.completed += 1;
                    let queued_ms = picked.duration_since(job.submitted).as_secs_f64() * 1e3;
                    let _ = job.resp.send(GenResponse { result, queued_ms, e2e_ms: e2e });
                }
                Err(e) => panic!("generation failed: {e:#}"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PolicyKind, Variant};
    use crate::scheduler::GenRequest;

    fn test_server(policy: PolicyKind, max_batch: usize, queue_depth: usize) -> Server {
        let mut scfg = ServerConfig::default();
        scfg.max_batch = max_batch;
        scfg.queue_depth = queue_depth;
        let mut fc = FastCacheConfig::with_policy(policy);
        fc.enable_str = false;
        Server::start(scfg, fc, || Ok(DitModel::native(Variant::S, 1)))
    }

    #[test]
    fn serves_requests_end_to_end() {
        let server = test_server(PolicyKind::FastCache, 4, 16);
        let mut rxs = Vec::new();
        for i in 0..6 {
            rxs.push(server.submit(GenRequest::simple(i, 100 + i, 4)).unwrap());
        }
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.result.latent.data().iter().all(|v| v.is_finite()));
            assert!(resp.e2e_ms >= resp.queued_ms);
        }
        let report = server.shutdown();
        assert_eq!(report.completed, 6);
        assert!(report.throughput_rps() > 0.0);
    }

    #[test]
    fn backpressure_when_queue_full() {
        // Tiny queue; flood it faster than the worker drains.
        let server = test_server(PolicyKind::NoCache, 1, 1);
        let mut saw_full = false;
        let mut rxs = Vec::new();
        for i in 0..50 {
            match server.submit(GenRequest::simple(i, i, 8)) {
                Ok(rx) => rxs.push(rx),
                Err(SubmitError::QueueFull) => {
                    saw_full = true;
                    break;
                }
                Err(e) => panic!("unexpected: {e}"),
            }
        }
        assert!(saw_full, "bounded queue never pushed back");
        for rx in rxs {
            let _ = rx.recv();
        }
        server.shutdown();
    }

    #[test]
    fn submit_after_shutdown_fails() {
        let server = test_server(PolicyKind::NoCache, 1, 4);
        let rx = server.submit(GenRequest::simple(0, 0, 2)).unwrap();
        let _ = rx.recv();
        // Shutdown consumes the server; a clone of tx would be Closed.
        let report = server.shutdown();
        assert_eq!(report.completed, 1);
    }

    #[test]
    fn batches_form_under_load() {
        let server = test_server(PolicyKind::FastCache, 4, 32);
        let mut rxs = Vec::new();
        for i in 0..12 {
            rxs.push(server.submit(GenRequest::simple(i, 7 + i, 4)).unwrap());
        }
        for rx in rxs {
            let _ = rx.recv().unwrap();
        }
        let report = server.shutdown();
        assert_eq!(report.completed, 12);
        assert!(
            report.mean_batch_size() > 1.0,
            "no batching happened: {}",
            report.mean_batch_size()
        );
    }
}
