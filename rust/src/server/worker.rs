//! The shard worker: continuous batching over the unified lane stepper,
//! one instance per dispatcher shard.
//!
//! Every config runs through `LaneStepper::step`, which batches whatever
//! aligns (full-token Compute sites through the B=4 artifact) and runs
//! the rest per-lane. Lanes at different step indices coexist in one
//! active set; finished lanes retire and queued jobs are admitted at step
//! boundaries, so the shard never drains before taking new work.
//! Admission is SLA-aware: the shard's `JobQueue` pops deadline-tagged
//! jobs (earliest absolute deadline first) ahead of best-effort ones,
//! jobs whose absolute deadline already expired are SHED at pop time
//! (a typed `Expired` rejection, counted per class), and the shard
//! records per-class deadline-hit rates. Responses travel as
//! `api::Event`s: optional per-step progress ticks for streaming
//! submissions, then exactly one terminal `api::Outcome` — the same
//! types the network front door (`crate::net`) puts on the wire. After each step the shard
//! publishes its predicted remaining FLOPs so the dispatcher can route by
//! least predicted load.
//!
//! Warm start (when a `WarmStore` is threaded in): at admission a lane
//! adopts converged affine fits — and an L2C policy a calibrated delta
//! profile — recorded by previously served traffic; at retirement it
//! publishes its own back. Lookups are snapshots, so in-flight lanes
//! never observe store mutations.

use std::panic::AssertUnwindSafe;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::cache::calibrate::{calibrated_l2c, DeltaProfile};
use crate::cache::AffineFit;
use crate::config::{FastCacheConfig, PolicyKind, ServerConfig};
use crate::faults::{FaultPanic, FaultPlan};
use crate::metrics::LatencyHistogram;
use crate::model::DitModel;
use crate::obs::{EventKind, FlightRecorder, Registry, ShardMetrics, StepObserver, TraceEvent, NON_LAYER};
use crate::scheduler::{GenRequest, Lane, LaneStepper, ScheduleCache};
use crate::store::{ModelFingerprint, StoreStats, WarmStore};

use crate::api::{
    ErrorCode, Event, GenClient, GenResponse, NetStats, Outcome, Progress, Reject,
    ResponseStream,
};

use super::dispatch::{Dispatcher, ShardLoad};
use super::queue::{Job, JobQueue};
use super::supervisor::{HealthState, Supervisor};

/// One shard's slice of the final report.
#[derive(Debug)]
pub struct ShardReport {
    pub shard: usize,
    pub completed: u64,
    pub e2e: LatencyHistogram,
    /// Admission latency: submit → lane admitted into the active set (ms).
    pub admission_wait: LatencyHistogram,
    /// This shard thread's lifetime (spawn → drain), seconds.
    pub wall_s: f64,
    /// Unified-stepper invocations; each advances every active lane by
    /// one denoise step.
    pub step_calls: u64,
    /// Occupancy integral: Σ over step calls of the active-lane count.
    pub lane_steps: u64,
    /// FLOPs burnt in padded B=4 batch slots across completed lanes.
    pub padded_flops: u64,
    /// SLA accounting: deadline-tagged jobs served / of those, how many
    /// finished within their deadline / best-effort jobs served.
    pub deadline_jobs: u64,
    pub deadline_hits: u64,
    pub best_effort_jobs: u64,
    /// Deadline-class jobs dropped unserved at pop time because their
    /// absolute deadline had already passed (best-effort jobs carry no
    /// deadline and are structurally never shed). Shed jobs are counted
    /// here ONLY — not in `completed`/`deadline_jobs`.
    pub deadline_sheds: u64,
    /// Lanes that warm-started from the cross-request store (≥ 1 warm
    /// layer or a calibrated policy) / total layers warm-started.
    pub warm_admissions: u64,
    pub warm_layers: u64,
    /// High-water mark of this shard's kernel scratch arena, bytes —
    /// the entire transient working set of the native block kernels
    /// (reused across every lane and step; stabilizes after the first
    /// step, so steady-state block calls allocate nothing).
    pub scratch_bytes: u64,
    /// Effective intra-op kernel threads this shard used: the configured
    /// `ServerConfig::threads` after the `workers × threads ≤ cores`
    /// clamp applied at startup. 1 means fully serial kernels.
    pub threads: u64,
    /// Requests answered `ErrorCode::Internal` because a panic (or a step
    /// error) quarantined their lane. Deadline-tagged ones ALSO count in
    /// `deadline_sheds`, so a fault is always an SLA miss.
    pub internal_errors: u64,
    /// Deadline lanes the degrade ladder touched at least once / total
    /// ladder rungs applied across all lanes. Both 0 unless
    /// `ServerConfig::degrade` is on AND some lane fell behind budget.
    pub degraded_lanes: u64,
    pub degrade_rungs: u64,
    /// Supervised restarts: flap-threshold teardowns plus watchdog
    /// escalations. 0 unless the supervisor knobs are armed.
    pub restarts: u64,
    /// Jobs the stuck-step watchdog shed from this shard's queue while
    /// it was wedged (deadline-tagged ones ALSO count in
    /// `deadline_sheds`, so watchdog sheds are SLA misses).
    pub watchdog_sheds: u64,
}

impl ShardReport {
    /// Fraction of deadline-class jobs that met their budget. Shed jobs
    /// count in the denominator — dropping an expired job is an SLA
    /// failure, and excluding it would let a shedding server report a
    /// perfect hit rate. `None` when no deadline-class traffic arrived.
    pub fn deadline_hit_rate(&self) -> Option<f64> {
        let attempted = self.deadline_jobs + self.deadline_sheds;
        if attempted == 0 {
            None
        } else {
            Some(self.deadline_hits as f64 / attempted as f64)
        }
    }
}

/// Aggregate report when the server shuts down: the merge of every
/// shard's report, with the per-shard breakdown preserved.
#[derive(Debug)]
pub struct ServerReport {
    pub completed: u64,
    pub e2e: LatencyHistogram,
    /// Admission latency: submit → lane admitted into a shard (ms).
    pub admission_wait: LatencyHistogram,
    /// Server lifetime (start → shutdown join), seconds.
    pub wall_s: f64,
    pub step_calls: u64,
    pub lane_steps: u64,
    pub padded_flops: u64,
    pub deadline_jobs: u64,
    pub deadline_hits: u64,
    pub best_effort_jobs: u64,
    /// Deadline-class jobs shed unserved (expired before admission),
    /// summed over shards.
    pub deadline_sheds: u64,
    /// Deadline-tagged requests refused at the NETWORK DOOR (`Busy`
    /// frame before any queue slot was taken). Folded in by
    /// [`ServerReport::absorb_net`]; always 0 for in-process-only runs.
    pub door_sheds: u64,
    /// Warm-start accounting, summed over shards.
    pub warm_admissions: u64,
    pub warm_layers: u64,
    /// Largest per-shard kernel-scratch high-water mark, bytes (each
    /// shard's arena is independent, so the max is the honest figure).
    pub scratch_bytes: u64,
    /// Largest effective intra-op thread count across shards (every shard
    /// applies the same `workers × threads ≤ cores` clamp, so in practice
    /// they agree; max keeps the merge honest if they ever diverge).
    pub threads: u64,
    /// Fault-containment accounting, summed over shards: requests answered
    /// `Internal` after a quarantine, lanes the degrade ladder touched,
    /// and total ladder rungs applied.
    pub internal_errors: u64,
    pub degraded_lanes: u64,
    pub degrade_rungs: u64,
    /// Self-healing accounting: supervised shard restarts and
    /// watchdog-shed jobs, summed over shards.
    pub shard_restarts: u64,
    pub watchdog_sheds: u64,
    /// Poisoned-request blocklist accounting, from the supervisor:
    /// requests refused at admission with `ErrorCode::Poisoned`, the
    /// deadline-tagged subset (SLA misses), and distinct request ids
    /// ever blocklisted. All 0 unless `poison_after > 0`.
    pub poisoned_rejections: u64,
    pub poisoned_sheds: u64,
    pub blocklisted: u64,
    /// Warm-start store counters/occupancy at shutdown (`None` when the
    /// server ran without a store).
    pub store: Option<StoreStats>,
    /// Network-door counters (`None` when no listener served traffic).
    pub net: Option<NetStats>,
    /// Per-shard breakdown (one entry per worker thread).
    pub shards: Vec<ShardReport>,
}

impl ServerReport {
    pub(crate) fn merge(
        shards: Vec<ShardReport>,
        wall_s: f64,
        store: Option<StoreStats>,
    ) -> ServerReport {
        let mut r = ServerReport {
            completed: 0,
            e2e: LatencyHistogram::new(),
            admission_wait: LatencyHistogram::new(),
            wall_s,
            step_calls: 0,
            lane_steps: 0,
            padded_flops: 0,
            deadline_jobs: 0,
            deadline_hits: 0,
            best_effort_jobs: 0,
            deadline_sheds: 0,
            door_sheds: 0,
            warm_admissions: 0,
            warm_layers: 0,
            scratch_bytes: 0,
            threads: 1,
            internal_errors: 0,
            degraded_lanes: 0,
            degrade_rungs: 0,
            shard_restarts: 0,
            watchdog_sheds: 0,
            poisoned_rejections: 0,
            poisoned_sheds: 0,
            blocklisted: 0,
            store,
            net: None,
            shards: Vec::new(),
        };
        for s in &shards {
            r.completed += s.completed;
            r.e2e.merge(&s.e2e);
            r.admission_wait.merge(&s.admission_wait);
            r.step_calls += s.step_calls;
            r.lane_steps += s.lane_steps;
            r.padded_flops += s.padded_flops;
            r.deadline_jobs += s.deadline_jobs;
            r.deadline_hits += s.deadline_hits;
            r.best_effort_jobs += s.best_effort_jobs;
            r.deadline_sheds += s.deadline_sheds;
            r.warm_admissions += s.warm_admissions;
            r.warm_layers += s.warm_layers;
            r.scratch_bytes = r.scratch_bytes.max(s.scratch_bytes);
            r.threads = r.threads.max(s.threads);
            r.internal_errors += s.internal_errors;
            r.degraded_lanes += s.degraded_lanes;
            r.degrade_rungs += s.degrade_rungs;
            r.shard_restarts += s.restarts;
            r.watchdog_sheds += s.watchdog_sheds;
        }
        r.shards = shards;
        r
    }

    pub fn throughput_rps(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.completed as f64 / self.wall_s
        }
    }

    /// Mean number of lanes advancing together per step call — the
    /// continuous-batching occupancy. > 1 means batching happened.
    pub fn mean_batch_size(&self) -> f64 {
        if self.step_calls == 0 {
            0.0
        } else {
            self.lane_steps as f64 / self.step_calls as f64
        }
    }

    /// Alias with the serving-literature name.
    pub fn occupancy(&self) -> f64 {
        self.mean_batch_size()
    }

    /// Fraction of deadline-class jobs that finished within their
    /// deadline. Shed jobs count as misses (they were dropped unserved)
    /// — and so do deadline-tagged requests refused at the network door
    /// or rejected at admission as `Poisoned` — so the rate cannot be
    /// inflated by shedding or refusing anywhere in the stack. (Watchdog
    /// sheds already live inside `deadline_sheds`.) `None` when the
    /// workload had no deadline-class jobs.
    pub fn deadline_hit_rate(&self) -> Option<f64> {
        let attempted =
            self.deadline_jobs + self.deadline_sheds + self.door_sheds + self.poisoned_sheds;
        if attempted == 0 {
            None
        } else {
            Some(self.deadline_hits as f64 / attempted as f64)
        }
    }

    /// Fold the network door's counters into this report (called by
    /// `net::NetServer::shutdown` after the inner server drains).
    /// Deadline-tagged door refusals enter the SLA denominator here.
    pub fn absorb_net(&mut self, stats: NetStats) {
        self.door_sheds += stats.door_sheds_deadline;
        self.net = Some(stats);
    }
}

/// A running server instance: a dispatcher over `ServerConfig.workers`
/// shard threads.
pub struct Server {
    dispatcher: Dispatcher,
    /// Path the warm store snapshots to at shutdown / restored from at
    /// start (`ServerConfig::warm_snapshot`; `None` = no persistence).
    warm_snapshot: Option<String>,
    /// Periodic-snapshot ticker thread (armed by
    /// `ServerConfig::warm_snapshot_every > 0`): stop-sender + join
    /// handle. Each tick saves atomically (tmp file + rename), so a
    /// crash between shutdowns loses at most one period of published
    /// fits instead of all of them.
    snapshot_ticker: Option<(mpsc::Sender<()>, std::thread::JoinHandle<()>)>,
}

impl Server {
    /// Start the shards. `model_factory` runs once per shard, ON the
    /// shard's thread (PJRT clients are not shared across threads);
    /// weight generation is seed-deterministic, so every shard serves
    /// identical weights. When `fc.warm_start` is on, a fresh warm-start
    /// store (budgeted by `scfg.warm_budget_bytes`) is built and shared
    /// by every shard.
    pub fn start<F>(scfg: ServerConfig, fc: FastCacheConfig, model_factory: F) -> Server
    where
        F: Fn() -> Result<DitModel> + Send + Sync + 'static,
    {
        let store = if fc.warm_start {
            Some(Arc::new(WarmStore::new(scfg.warm_budget_bytes, scfg.workers.max(1))))
        } else {
            None
        };
        Server::start_with_store(scfg, fc, store, model_factory)
    }

    /// Start the shards against a caller-owned warm-start store — the
    /// fleet pattern: the store outlives any one server instance, so a
    /// restarted (or blue/green-swapped) process starts warm from the
    /// traffic its predecessor served. `None` disables warm-start
    /// regardless of `fc.warm_start`.
    pub fn start_with_store<F>(
        scfg: ServerConfig,
        fc: FastCacheConfig,
        store: Option<Arc<WarmStore>>,
        model_factory: F,
    ) -> Server
    where
        F: Fn() -> Result<DitModel> + Send + Sync + 'static,
    {
        let warm_snapshot = scfg.warm_snapshot.clone();
        let dispatcher = Dispatcher::start(&scfg, &fc, store, model_factory);
        // Restore the warm store from disk, if a snapshot path is
        // configured and a file is there. Corruption policy: ANY decode
        // failure (bad magic, checksum, dims, a fault-injected flip)
        // degrades to a cold store — logged, never fatal.
        if let (Some(path), Some(store)) = (&warm_snapshot, dispatcher.warm_store()) {
            if std::path::Path::new(path).exists() {
                let faults = dispatcher.fault_plan();
                match store.load_snapshot(std::path::Path::new(path), faults.as_deref()) {
                    Ok(n) => eprintln!("warm store: restored {n} entries from {path}"),
                    Err(e) => {
                        eprintln!("warm store: snapshot {path} rejected ({e}); starting cold");
                    }
                }
            }
        }
        // Periodic snapshots: a ticker thread saves the store every
        // `warm_snapshot_every` seconds. `save_snapshot` is atomic (tmp
        // file + rename), so a reader — or a crash — never observes a
        // half-written file.
        let snapshot_ticker = match (&warm_snapshot, dispatcher.warm_store()) {
            (Some(path), Some(store)) if scfg.warm_snapshot_every > 0.0 => {
                let (stop_tx, stop_rx) = mpsc::channel::<()>();
                let path = path.clone();
                let period = Duration::from_secs_f64(scfg.warm_snapshot_every);
                let handle = std::thread::Builder::new()
                    .name("fastcache-warm-snapshot".into())
                    .spawn(move || loop {
                        match stop_rx.recv_timeout(period) {
                            Ok(()) | Err(mpsc::RecvTimeoutError::Disconnected) => return,
                            Err(mpsc::RecvTimeoutError::Timeout) => {}
                        }
                        match store.save_snapshot(std::path::Path::new(&path)) {
                            Ok(n) => eprintln!("warm store: periodic snapshot of {n} entries to {path}"),
                            Err(e) => eprintln!("warm store: periodic snapshot to {path} failed: {e}"),
                        }
                    })
                    .expect("spawning warm-snapshot ticker");
                Some((stop_tx, handle))
            }
            _ => None,
        };
        Server { dispatcher, warm_snapshot, snapshot_ticker }
    }

    /// Number of worker shards serving this instance.
    pub fn workers(&self) -> usize {
        self.dispatcher.workers()
    }

    fn submit_inner(&self, req: &GenRequest, progress: bool) -> Result<ResponseStream, Reject> {
        let id = req.id;
        let (rtx, rrx) = mpsc::channel();
        let job =
            Job { req: req.clone(), resp: rtx, submitted: Instant::now(), cost: 0, progress };
        self.dispatcher.submit(job)?;
        Ok(ResponseStream::new(id, rrx))
    }

    /// Submit a request. The stream yields exactly one terminal
    /// `Outcome`: `Completed` for served requests, `Rejected(Expired)`
    /// for deadline-tagged requests dropped because their deadline
    /// expired while queued. Backpressure comes back as `Err(Busy)`.
    pub fn submit(&self, req: &GenRequest) -> Result<ResponseStream, Reject> {
        self.submit_inner(req, false)
    }

    /// Like [`Server::submit`], plus per-step `Event::Progress` ticks.
    pub fn submit_streaming(&self, req: &GenRequest) -> Result<ResponseStream, Reject> {
        self.submit_inner(req, true)
    }

    /// Submit, sleeping through backpressure until a shard accepts the
    /// request. Only fails when the server is shutting down.
    pub fn submit_blocking(&self, req: &GenRequest) -> Result<ResponseStream, Reject> {
        loop {
            match self.submit(req) {
                Ok(rx) => return Ok(rx),
                Err(rej) if rej.code == ErrorCode::Busy => {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                Err(rej) => return Err(rej),
            }
        }
    }

    /// The live telemetry registry: scrape series at any time with
    /// [`Registry::series`]. The shutdown report is its final snapshot.
    pub fn registry(&self) -> Arc<Registry> {
        self.dispatcher.registry()
    }

    /// The flight recorder (`None` unless `trace_sample_rate > 0`).
    pub fn recorder(&self) -> Option<Arc<FlightRecorder>> {
        self.dispatcher.recorder()
    }

    /// The armed fault plan, if `ServerConfig::fault_plan` configured one
    /// (the network door injects socket resets from it).
    pub fn fault_plan(&self) -> Option<Arc<crate::faults::FaultPlan>> {
        self.dispatcher.fault_plan()
    }

    /// The shard supervisor (health states, blocklist counters).
    pub fn supervisor(&self) -> Arc<Supervisor> {
        self.dispatcher.supervisor()
    }

    /// One liveness observation: per-shard health states plus restart
    /// and blocklist totals. This is what the wire `Health` frame
    /// answers with — cheap enough to call at any time, including while
    /// the server drains.
    pub fn health_snapshot(&self) -> super::supervisor::HealthSnapshot {
        let sup = self.dispatcher.supervisor();
        let restarts =
            self.registry().shards().iter().map(|s| s.restarts.get()).sum();
        super::supervisor::HealthSnapshot {
            states: sup.states(),
            restarts,
            blocklisted: sup.blocklisted(),
        }
    }

    /// Close every shard queue and wait for the shards to drain. When a
    /// snapshot path is configured, the warm store's contents are saved
    /// after the drain (so the snapshot includes everything the final
    /// burst published).
    pub fn shutdown(self) -> ServerReport {
        // Stop the periodic-snapshot ticker first: the final save below
        // must not race a tick's rename.
        if let Some((stop_tx, handle)) = self.snapshot_ticker {
            drop(stop_tx);
            let _ = handle.join();
        }
        let store = self.dispatcher.warm_store();
        let report = self.dispatcher.shutdown();
        if let (Some(path), Some(store)) = (&self.warm_snapshot, store) {
            match store.save_snapshot(std::path::Path::new(path)) {
                Ok(n) => eprintln!("warm store: saved {n} entries to {path}"),
                Err(e) => eprintln!("warm store: snapshot save to {path} failed: {e}"),
            }
        }
        report
    }
}

impl GenClient for Server {
    fn submit(&self, req: &GenRequest) -> Result<ResponseStream, Reject> {
        Server::submit(self, req)
    }

    fn submit_streaming(&self, req: &GenRequest) -> Result<ResponseStream, Reject> {
        Server::submit_streaming(self, req)
    }
}

/// A lane's serving-side envelope, parallel to the lane vector.
///
/// Besides the response plumbing it snapshots everything the lane was
/// built FROM at admission — the warm fits it adopted, the calibration
/// profile its policy was built with, and every degrade rung applied
/// since — so that after a panic quarantines a batch-mate, the survivor
/// can be rebuilt and solo-replayed to its exact pre-panic state even if
/// the warm store has mutated in the meantime. Replay is bit-exact by
/// the batched-equals-solo parity invariant the stepper tests pin.
struct Inflight {
    job: Job,
    admitted: Instant,
    /// Warm fits adopted at admission (`None` when no store / not used).
    warm: Option<Vec<Option<AffineFit>>>,
    /// L2C calibration profile the lane's policy was built from.
    profile: Option<DeltaProfile>,
    /// Degrade rungs applied, tagged with the lane step index they were
    /// applied BEFORE (replay re-applies them at the same boundaries).
    degrade_log: Vec<(usize, DegradeRung)>,
}

/// One rung of the degrade ladder, in escalation order: widen the cache
/// skip region, tighten the STR keep-ratio, truncate the remaining steps.
#[derive(Clone, Copy, Debug)]
enum DegradeRung {
    Relax(f64),
    TightenStr(f64),
    Truncate(usize),
}

/// Cache-threshold multiplier for rung 1 and STR keep-threshold
/// multiplier for rung 2. Fixed, not configured: the ladder's knob is
/// its DEPTH (`ServerConfig::degrade_rungs`), not per-rung magnitudes.
const DEGRADE_RELAX_FACTOR: f64 = 2.0;
const DEGRADE_STR_FACTOR: f64 = 4.0;

fn apply_rung(lane: &mut Lane, rung: DegradeRung) {
    match rung {
        DegradeRung::Relax(f) => lane.degrade_relax_policy(f),
        DegradeRung::TightenStr(t) => lane.degrade_tighten_str(t),
        DegradeRung::Truncate(rem) => lane.degrade_truncate_steps(rem),
    }
}

/// Solo-replay survivors onto a FRESH stepper after a quarantine or a
/// supervised restart: rebuild each lane from its admission snapshot
/// (calibration profile, warm fits), re-apply its logged degrade rungs
/// at the exact boundaries they originally hit, and re-step it to its
/// pre-fault step index — bit-exact by the batched-equals-solo parity
/// invariant. Replay runs UNOBSERVED (pre-fault steps were already
/// counted once) and beats the supervisor heartbeat per replayed step so
/// a long replay is never mistaken for a stall. A survivor whose replay
/// itself fails answers `Internal` like the faulted lane did.
#[allow(clippy::too_many_arguments)]
fn replay_survivors(
    stepper: &mut LaneStepper<'_>,
    schedules: &Mutex<ScheduleCache>,
    metrics: &ShardMetrics,
    supervisor: &Supervisor,
    shard_id: usize,
    l2c_thr: f64,
    layers: usize,
    survivors: Vec<(Inflight, usize)>,
    lanes: &mut Vec<Lane>,
    inflight: &mut Vec<Inflight>,
) {
    for (fl, target) in survivors {
        let schedule = schedules.lock().expect("schedule cache poisoned").get(fl.job.req.steps);
        let replayed = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let mut lane = match &fl.profile {
                Some(profile) => {
                    let policy = Box::new(calibrated_l2c(profile, l2c_thr, layers));
                    stepper.lane_with_policy(&fl.job.req, schedule, policy)
                }
                None => stepper.make_lane(&fl.job.req, schedule),
            };
            if let Some(w) = &fl.warm {
                lane.warm_start_fits(w);
            }
            let mut next_rung = 0;
            while lane.step_index() < target {
                while next_rung < fl.degrade_log.len()
                    && fl.degrade_log[next_rung].0 == lane.step_index()
                {
                    apply_rung(&mut lane, fl.degrade_log[next_rung].1);
                    next_rung += 1;
                }
                supervisor.beat(shard_id);
                stepper.step(std::slice::from_mut(&mut lane))?;
            }
            // Rungs logged at exactly the pre-fault boundary were
            // applied before the step that never completed.
            while next_rung < fl.degrade_log.len()
                && fl.degrade_log[next_rung].0 == lane.step_index()
            {
                apply_rung(&mut lane, fl.degrade_log[next_rung].1);
                next_rung += 1;
            }
            Ok::<Lane, anyhow::Error>(lane)
        }));
        match replayed {
            Ok(Ok(lane)) => {
                lanes.push(lane);
                inflight.push(fl);
            }
            _ => {
                metrics.internal_errors.inc();
                if fl.job.req.deadline_ms.is_some() {
                    metrics.deadline_sheds.inc();
                }
                let _ = fl.job.resp.send(Event::Done(Outcome::Rejected(Reject::internal(
                    fl.job.req.id,
                    "survivor replay failed after quarantine",
                ))));
            }
        }
    }
}

/// Publish this shard's predicted load for the dispatcher's router.
fn publish_load(load: &ShardLoad, lanes: &[Lane]) {
    use std::sync::atomic::Ordering;
    let remaining: u64 = lanes.iter().map(Lane::remaining_flops_estimate).sum();
    load.active_flops.store(remaining, Ordering::Relaxed);
    load.active_lanes.store(lanes.len(), Ordering::Relaxed);
}

/// Everything one shard thread needs from the dispatcher: its identity,
/// configs, queue/load plumbing, and the (optional) shared warm store.
pub(crate) struct ShardCtx {
    pub id: usize,
    pub scfg: ServerConfig,
    pub fc: FastCacheConfig,
    pub queue: Arc<JobQueue>,
    pub load: Arc<ShardLoad>,
    pub schedules: Arc<Mutex<ScheduleCache>>,
    pub warm_store: Option<Arc<WarmStore>>,
    /// This shard's live telemetry series (registered in the dispatcher's
    /// [`Registry`]). The shard updates them lock-free on the hot path;
    /// the shutdown `ShardReport` is their final snapshot.
    pub metrics: Arc<ShardMetrics>,
    /// Shared flight recorder (`None` unless tracing is enabled).
    pub recorder: Option<Arc<FlightRecorder>>,
    /// Shared deterministic fault plan (`None` unless `--fault-plan` /
    /// `[faults]` configured one — the default). When absent, no fault
    /// branch in the serve loop is ever taken.
    pub faults: Option<Arc<FaultPlan>>,
    /// The shard supervisor: this shard bumps its step heartbeat through
    /// it, reports quarantines for flap control, and honors its restart
    /// requests. Always present; inert with all knobs at 0.
    pub supervisor: Arc<Supervisor>,
}

/// One shard's serve loop: continuous batching with SLA-aware admission,
/// expired-deadline shedding at pop time, and (when a store is threaded
/// in) warm-start at admission / publish at retirement.
pub(crate) fn shard_loop<F>(ctx: ShardCtx, model_factory: &F) -> ShardReport
where
    F: Fn() -> Result<DitModel>,
{
    use std::sync::atomic::Ordering;

    let ShardCtx {
        id: shard_id,
        scfg,
        fc,
        queue,
        load,
        schedules,
        warm_store,
        metrics,
        recorder,
        faults,
        supervisor,
    } = ctx;
    let (queue, load, schedules) = (queue.as_ref(), load.as_ref(), schedules.as_ref());
    let warm_store = warm_store.as_deref();

    // If this shard dies (model-load failure, panicked step), close and
    // drain its queue on the way out so submitters observe Closed /
    // disconnected responses instead of hanging forever — the old
    // single-worker mpsc design gave that for free when the worker's
    // Receiver dropped. Runs on normal exit too, where it is a no-op
    // (queue already closed and drained).
    struct DrainOnExit<'q>(&'q JobQueue);
    impl Drop for DrainOnExit<'_> {
        fn drop(&mut self) {
            self.0.close();
            while self.0.try_pop().is_some() {}
        }
    }
    let _drain_guard = DrainOnExit(queue);

    let mut model = model_factory().expect("model load failed");
    if scfg.int8 {
        // Opt-in int8 serving: quantize every packed block once, up
        // front, on this shard's own copy — the f32 panels stay resident
        // for the layers that remain full-precision (LN modulation,
        // temb/embed/final).
        model.quantize_int8();
    }
    // Intra-op threads: the configured count after the global
    // `workers × threads ≤ cores` clamp. Bit-identical to serial, so
    // this only changes wall time, never outputs.
    let threads = scfg.effective_threads();
    // Keep a copy of the cache config: quarantine recovery rebuilds the
    // stepper from scratch (the unwound one's arena state is untrusted).
    let fc_cfg = fc.clone();
    let mut stepper = LaneStepper::with_threads(&model, fc, threads);
    metrics.threads.set(threads as u64);
    // Hand the stepper its observation channel: per-step counters flush
    // into this shard's registry series; traced lanes' decision events go
    // to the shared flight recorder. Observation only — the stepper's
    // decision path never reads any of it.
    stepper.set_observer(StepObserver {
        shard: shard_id as u32,
        metrics: Arc::clone(&metrics),
        recorder: recorder.clone(),
    });
    if let Some(plan) = &faults {
        stepper.set_fault_plan(shard_id as u32, Arc::clone(plan));
    }
    // Guard against unvalidated configs: max_batch = 0 must degrade to
    // solo serving, not livelock the admission loop.
    let max_batch = scfg.max_batch.max(1);
    // Degrade ladder depth: 0 = ladder off (the default), so the walk
    // below is never even entered and best-effort behavior is untouched.
    let degrade_depth = if scfg.degrade { scfg.degrade_rungs.min(3) } else { 0 };
    // Warm-start keys: same variant + weight seed ⇒ transferable fits.
    let fp = ModelFingerprint { variant: scfg.variant, weight_seed: scfg.weight_seed };
    let (pol_kind, l2c_thr, publish_min, fits_used) = {
        let f = stepper.fc();
        // Affine fits only influence execution through the FastCache
        // policy's Approx action or the STR static-row bypass — for any
        // other config, adopting/publishing them would burn store budget
        // and lookups on entries no decision can ever read.
        let fits_used = f.policy == PolicyKind::FastCache || f.enable_str;
        (f.policy, f.l2c_threshold, f.fit_min_updates.max(1), fits_used)
    };
    let layers = model.cfg.layers;

    let mut lanes: Vec<Lane> = Vec::new();
    let mut inflight: Vec<Inflight> = Vec::new();
    let mut closed = false;

    loop {
        // Watchdog escalation: the watchdog flagged a stall while a step
        // was wedged, shed this shard's queue, and requested a restart —
        // which only this thread can perform, because it owns the
        // stepper. Now that the wedged step has returned, tear down and
        // rebuild (fresh model + stepper) and replay every active lane
        // at its exact step index.
        if supervisor.take_restart_request(shard_id) {
            eprintln!(
                "shard {shard_id}: watchdog requested a restart; replaying {} active lane(s)",
                lanes.len()
            );
            supervisor.set_state(shard_id, HealthState::Restarting);
            metrics.restarts.inc();
            match model_factory() {
                Ok(mut m) => {
                    if scfg.int8 {
                        m.quantize_int8();
                    }
                    model = m;
                }
                Err(e) => eprintln!(
                    "shard {shard_id}: model rebuild failed ({e}); \
                     restarting on resident weights"
                ),
            }
            stepper = LaneStepper::with_threads(&model, fc_cfg.clone(), threads);
            let old_lanes = std::mem::take(&mut lanes);
            let old_inflight = std::mem::take(&mut inflight);
            let survivors: Vec<(Inflight, usize)> = old_inflight
                .into_iter()
                .zip(old_lanes.iter().map(Lane::step_index))
                .collect();
            drop(old_lanes);
            replay_survivors(
                &mut stepper,
                schedules,
                &metrics,
                &supervisor,
                shard_id,
                l2c_thr,
                layers,
                survivors,
                &mut lanes,
                &mut inflight,
            );
            stepper.set_observer(StepObserver {
                shard: shard_id as u32,
                metrics: Arc::clone(&metrics),
                recorder: recorder.clone(),
            });
            if let Some(plan) = &faults {
                stepper.set_fault_plan(shard_id as u32, Arc::clone(plan));
            }
            supervisor.finish_restart(shard_id);
            publish_load(load, &lanes);
        }
        // Admission, at the step boundary: fill free lane slots. The
        // queue pops deadline-tagged jobs first, so SLA traffic jumps
        // ahead of best-effort exactly here. Block only when idle;
        // otherwise take whatever is already queued.
        while !closed && lanes.len() < max_batch {
            // Fault injection: an armed popdelay spec stalls this shard's
            // admission here — deterministically, before the pop — so
            // deadline erosion under slow admission can be reproduced.
            if let Some(plan) = faults.as_deref() {
                if let Some(ms) = plan.pop_delay_ms(shard_id as u32) {
                    std::thread::sleep(Duration::from_millis(ms));
                }
            }
            let job = if lanes.is_empty() {
                match queue.pop_blocking() {
                    Some(j) => j,
                    None => {
                        closed = true;
                        break;
                    }
                }
            } else {
                match queue.try_pop() {
                    Some(j) => j,
                    None => break,
                }
            };
            // One admission instant, used for both the report histogram
            // and the per-response queued_ms — they must agree.
            let admitted = Instant::now();
            // Expired-deadline shedding at pop time: a job whose absolute
            // deadline already passed can only be served as a guaranteed
            // SLA miss, so drop it with a distinct outcome and spend the
            // lane slot on a job that can still hit. (The SLA-aware queue
            // pops earliest-deadline first, so expired jobs surface
            // immediately rather than lingering behind live ones.)
            if job.expired(admitted) {
                load.queued_flops.fetch_sub(job.cost, Ordering::Relaxed);
                metrics.deadline_sheds.inc();
                job.shed();
                continue;
            }
            let waited = admitted.duration_since(job.submitted);
            metrics.admission_wait.record(waited.as_secs_f64() * 1e3);
            // Traced lanes get a queue-wait stage span so the Chrome
            // timeline shows submit → admission alongside the step spans.
            if let Some(rec) = recorder.as_deref() {
                if rec.sampled(job.req.id) {
                    rec.push(TraceEvent {
                        ts_us: rec.now_us(),
                        dur_us: waited.as_micros() as u64,
                        shard: shard_id as u32,
                        lane: job.req.id,
                        step: 0,
                        layer: NON_LAYER,
                        kind: EventKind::Stage { stage: "queue_wait" },
                    });
                }
            }
            load.queued_flops.fetch_sub(job.cost, Ordering::Relaxed);
            let schedule = schedules.lock().expect("schedule cache poisoned").get(job.req.steps);
            // Warm start at admission: threshold policies calibrate from
            // the fleet delta profile (L2C — real site selection instead
            // of its structural prior); every policy's lanes adopt
            // converged affine fits. Both lookups clone — snapshot
            // semantics keep the in-flight lane deterministic.
            let mut calibrated = false;
            let mut profile_used: Option<DeltaProfile> = None;
            let mut lane = match warm_store {
                Some(store) if pol_kind == PolicyKind::L2C => {
                    match store.warm_profile(fp, job.req.steps) {
                        Some(profile) => {
                            calibrated = true;
                            let policy = Box::new(calibrated_l2c(&profile, l2c_thr, layers));
                            profile_used = Some(profile);
                            stepper.lane_with_policy(&job.req, schedule, policy)
                        }
                        None => stepper.make_lane(&job.req, schedule),
                    }
                }
                _ => stepper.make_lane(&job.req, schedule),
            };
            let mut warmed_layers = 0;
            let mut warm_snapshot: Option<Vec<Option<AffineFit>>> = None;
            if let (Some(store), true) = (warm_store, fits_used) {
                let warm = store.warm_fits(fp, pol_kind, job.req.steps, layers);
                warmed_layers = lane.warm_start_fits(&warm);
                warm_snapshot = Some(warm);
            }
            if calibrated || warmed_layers > 0 {
                metrics.warm_admissions.inc();
                metrics.warm_layers.add(warmed_layers as u64);
            }
            lanes.push(lane);
            inflight.push(Inflight {
                job,
                admitted,
                warm: warm_snapshot,
                profile: profile_used,
                degrade_log: Vec::new(),
            });
        }
        // Degrade ladder: when a deadline-tagged lane's own measured
        // throughput says it can no longer make its budget, trade quality
        // for latency one rung per step — widen the cache skip region,
        // tighten the STR keep-ratio, truncate the remaining schedule —
        // instead of running to a guaranteed miss. Best-effort lanes are
        // NEVER touched, `deadline_met` stays computed from the real e2e,
        // and every applied rung is logged for replay and reported in the
        // lane's result, so degradation can show up in the accounting but
        // never flatter it.
        if degrade_depth > 0 {
            for (lane, fl) in lanes.iter_mut().zip(inflight.iter_mut()) {
                let Some(budget) = fl.job.req.deadline_ms else { continue };
                let applied = lane.degrade_rungs() as usize;
                // Need at least one completed step to estimate throughput.
                if applied >= degrade_depth || lane.step_index() == 0 {
                    continue;
                }
                let elapsed = fl.job.submitted.elapsed().as_secs_f64() * 1e3;
                let remaining_budget = budget - elapsed;
                let per_flop = lane.active_ms() / lane.flops_done().max(1) as f64;
                let predicted = lane.remaining_flops_estimate() as f64 * per_flop;
                if predicted <= remaining_budget {
                    continue;
                }
                let rung = match applied {
                    0 => DegradeRung::Relax(DEGRADE_RELAX_FACTOR),
                    1 => DegradeRung::TightenStr(fc_cfg.tau_s * DEGRADE_STR_FACTOR),
                    _ => {
                        // Last resort: keep only as many steps as the
                        // budget can pay for at the lane's measured pace
                        // (at least one more, so the latent stays sane).
                        let per_step = lane.active_ms() / lane.step_index() as f64;
                        let fit = if per_step > 0.0 {
                            (remaining_budget / per_step).floor().max(1.0) as usize
                        } else {
                            1
                        };
                        DegradeRung::Truncate(fit)
                    }
                };
                if applied == 0 {
                    metrics.degraded_lanes.inc();
                }
                metrics.degrade_rungs.inc();
                fl.degrade_log.push((lane.step_index(), rung));
                apply_rung(lane, rung);
            }
        }

        // Publish BEFORE the (long) denoise step: admitted jobs left
        // queued_flops at admission and must show up in active_flops
        // immediately, or the router sees this shard as idle for the
        // whole step and piles new work onto the busiest shard.
        publish_load(load, &lanes);
        if lanes.is_empty() {
            if closed {
                break;
            }
            continue;
        }

        // One denoise step across the whole active set (lanes may sit at
        // different step indices — the stepper handles that). The call is
        // panic-isolated: a kernel panic attributed to one lane (a typed
        // `FaultPanic`) quarantines ONLY that lane; anything else — an
        // untyped panic or a step `Err` — quarantines the whole batch.
        // Either way the shard and the process survive.
        metrics.step_calls.inc();
        metrics.lane_steps.add(lanes.len() as u64);
        // One relaxed add per step call: the heartbeat the stuck-step
        // watchdog monitors. Observation only — never read by serving.
        supervisor.beat(shard_id);
        let step_outcome = std::panic::catch_unwind(AssertUnwindSafe(|| stepper.step(&mut lanes)));
        let failed: Option<Option<u64>> = match &step_outcome {
            Ok(Ok(())) => None,
            Ok(Err(_)) => Some(None),
            Err(payload) => Some(payload.downcast_ref::<FaultPanic>().map(|p| p.req_id)),
        };
        if let Some(faulted) = failed {
            let detail = match (&step_outcome, faulted) {
                (_, Some(id)) => {
                    format!("kernel panic while serving request {id}; lane quarantined")
                }
                (Ok(Err(e)), None) => format!("denoise step failed: {e}; batch quarantined"),
                _ => "unattributed panic in denoise step; batch quarantined".to_string(),
            };
            eprintln!("shard {shard_id}: {detail}");
            // Flap control FIRST, before any client learns of the fault:
            // a typed quarantine files its blocklist strike here, so by
            // the time the offender's `Internal` answer reaches the wire
            // an immediate resubmit already meets the blocklist.
            let flapping = supervisor.record_quarantine(shard_id, faulted);
            // Quarantine: the faulted lane(s) answer `Internal` — for
            // deadline-tagged requests that is an SLA miss, never a
            // vanished denominator. Survivors are rebuilt from their
            // admission snapshots and solo-replayed to their pre-panic
            // step index, which reproduces their state bit-exactly by
            // the batched-equals-solo parity invariant.
            let old_lanes = std::mem::take(&mut lanes);
            let old_inflight = std::mem::take(&mut inflight);
            let mut survivors: Vec<(Inflight, usize)> = Vec::new();
            for (lane, fl) in old_lanes.into_iter().zip(old_inflight) {
                let quarantined = faulted.map_or(true, |id| fl.job.req.id == id);
                if quarantined {
                    metrics.internal_errors.inc();
                    if fl.job.req.deadline_ms.is_some() {
                        metrics.deadline_sheds.inc();
                    }
                    let _ = fl.job.resp.send(Event::Done(Outcome::Rejected(Reject::internal(
                        fl.job.req.id,
                        detail.clone(),
                    ))));
                } else {
                    // The panic unwound out of the step before its index
                    // advanced, so step_index() IS the step to re-run to.
                    survivors.push((fl, lane.step_index()));
                }
            }
            // Past the configured flap threshold the supervisor orders a
            // full supervised restart: the quarantine path below already
            // rebuilds the stepper, so escalation adds a FRESH MODEL —
            // a corrupted weight bank must not survive the restart.
            if flapping {
                eprintln!(
                    "shard {shard_id}: quarantine flap threshold reached; supervised restart"
                );
                metrics.restarts.inc();
                match model_factory() {
                    Ok(mut m) => {
                        if scfg.int8 {
                            m.quantize_int8();
                        }
                        model = m;
                    }
                    Err(e) => eprintln!(
                        "shard {shard_id}: model rebuild failed ({e}); \
                         restarting on resident weights"
                    ),
                }
            }
            // The unwound stepper's arena/temb state is untrusted —
            // rebuild it. Replay runs UNOBSERVED (the panicked partial
            // step flushed no counters, and pre-panic steps were already
            // counted once) and UNARMED (a multi-shot panic spec must not
            // re-fire inside recovery).
            stepper = LaneStepper::with_threads(&model, fc_cfg.clone(), threads);
            replay_survivors(
                &mut stepper,
                schedules,
                &metrics,
                &supervisor,
                shard_id,
                l2c_thr,
                layers,
                survivors,
                &mut lanes,
                &mut inflight,
            );
            stepper.set_observer(StepObserver {
                shard: shard_id as u32,
                metrics: Arc::clone(&metrics),
                recorder: recorder.clone(),
            });
            if let Some(plan) = &faults {
                stepper.set_fault_plan(shard_id as u32, Arc::clone(plan));
            }
            if flapping {
                supervisor.finish_restart(shard_id);
            }
            publish_load(load, &lanes);
            continue;
        }

        // Progress ticks for streaming submissions: `step_index()` is the
        // count of completed steps after the call above, so a finishing
        // lane's last tick reads step == total just before its terminal
        // Completed event. Dropped receivers are ignored — an abandoned
        // stream must not kill the shard.
        for (lane, fl) in lanes.iter().zip(inflight.iter()) {
            if fl.job.progress {
                let _ = fl.job.resp.send(Event::Progress(Progress {
                    id: fl.job.req.id,
                    step: lane.step_index() as u32,
                    total: lane.total_steps() as u32,
                }));
            }
        }

        // Retire finished lanes; their slots free up for the next
        // admission round.
        let mut i = 0;
        while i < lanes.len() {
            if !lanes[i].is_done() {
                i += 1;
                continue;
            }
            let lane = lanes.swap_remove(i);
            let fl = inflight.swap_remove(i);
            // Publish at retirement: converged fits pool into the fleet
            // store; the lane's observed deltas fold into the profile.
            // Future admissions warm-start from what this lane learned.
            if let Some(store) = warm_store {
                let steps_total = lane.total_steps();
                if fits_used {
                    for (l, fit) in lane.converged_fits(publish_min) {
                        store.publish_fit(fp, pol_kind, steps_total, l, fit);
                    }
                }
                if let Some(deltas) = lane.delta_log() {
                    store.publish_profile(fp, steps_total, deltas);
                }
            }
            let result = lane.into_result();
            metrics.padded_flops.add(result.flops_padded);
            let e2e = fl.job.submitted.elapsed().as_secs_f64() * 1e3;
            let queued_ms = fl.admitted.duration_since(fl.job.submitted).as_secs_f64() * 1e3;
            let deadline_met = fl.job.req.deadline_ms.map(|budget| e2e <= budget);
            match deadline_met {
                Some(met) => {
                    metrics.deadline_jobs.inc();
                    if met {
                        metrics.deadline_hits.inc();
                    }
                }
                None => metrics.best_effort_jobs.inc(),
            }
            metrics.e2e.record(e2e);
            metrics.completed.inc();
            let _ = fl.job.resp.send(Event::Done(Outcome::Completed(GenResponse {
                result,
                queued_ms,
                e2e_ms: e2e,
                deadline_met,
            })));
        }

        // Refresh the router's view of this shard after admit+retire.
        publish_load(load, &lanes);
    }

    metrics.scratch_bytes.set(stepper.scratch_high_water_bytes() as u64);
    metrics.mark_finished();
    metrics.snapshot()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PolicyKind, Variant};
    use crate::scheduler::GenRequest;

    fn test_server(policy: PolicyKind, max_batch: usize, queue_depth: usize) -> Server {
        test_server_sharded(policy, max_batch, queue_depth, 1)
    }

    fn test_server_sharded(
        policy: PolicyKind,
        max_batch: usize,
        queue_depth: usize,
        workers: usize,
    ) -> Server {
        let scfg = ServerConfig { max_batch, queue_depth, workers, ..ServerConfig::default() };
        let mut fc = FastCacheConfig::with_policy(policy);
        fc.enable_str = false;
        Server::start(scfg, fc, || Ok(DitModel::native(Variant::S, 1)))
    }

    #[test]
    fn serves_requests_end_to_end() {
        let server = test_server(PolicyKind::FastCache, 4, 16);
        let mut rxs = Vec::new();
        for i in 0..6 {
            rxs.push(server.submit(&GenRequest::builder(i, 100 + i).steps(4).build().unwrap()).unwrap());
        }
        for rx in rxs {
            let resp = rx.wait().completed();
            assert!(resp.result.latent.data().iter().all(|v| v.is_finite()));
            assert!(resp.e2e_ms >= resp.queued_ms);
            assert_eq!(resp.deadline_met, None, "best-effort jobs carry no deadline verdict");
        }
        let report = server.shutdown();
        assert_eq!(report.completed, 6);
        assert_eq!(report.best_effort_jobs, 6);
        assert_eq!(report.deadline_hit_rate(), None);
        assert_eq!(report.deadline_sheds, 0);
        assert_eq!(report.store, None, "warm-start off: no store attached");
        assert!(report.throughput_rps() > 0.0);
        assert_eq!(report.admission_wait.count(), 6);
        assert_eq!(report.shards.len(), 1);
        assert_eq!(report.shards[0].completed, 6);
        assert!(
            report.scratch_bytes > 0,
            "native serving must report the kernel-arena high-water mark"
        );
        assert_eq!(report.scratch_bytes, report.shards[0].scratch_bytes);
    }

    #[test]
    fn backpressure_when_queue_full() {
        // Tiny queue; flood it faster than the worker drains.
        let server = test_server(PolicyKind::NoCache, 1, 1);
        let mut saw_full = false;
        let mut rxs = Vec::new();
        for i in 0..50 {
            match server.submit(&GenRequest::builder(i, i).steps(8).build().unwrap()) {
                Ok(rx) => rxs.push(rx),
                Err(rej) if rej.code == ErrorCode::Busy => {
                    saw_full = true;
                    break;
                }
                Err(rej) => panic!("unexpected: {rej}"),
            }
        }
        assert!(saw_full, "bounded queue never pushed back");
        for rx in rxs {
            let _ = rx.wait();
        }
        server.shutdown();
    }

    #[test]
    fn submit_after_shutdown_fails() {
        let server = test_server(PolicyKind::NoCache, 1, 4);
        let rx = server.submit(&GenRequest::builder(0, 0).steps(2).build().unwrap()).unwrap();
        let _ = rx.wait();
        // Shutdown consumes the server; a clone of tx would be Closed.
        let report = server.shutdown();
        assert_eq!(report.completed, 1);
    }

    #[test]
    fn batches_form_under_load() {
        let server = test_server(PolicyKind::FastCache, 4, 32);
        let mut rxs = Vec::new();
        for i in 0..12 {
            rxs.push(server.submit(&GenRequest::builder(i, 7 + i).steps(4).build().unwrap()).unwrap());
        }
        for rx in rxs {
            let _ = rx.wait();
        }
        let report = server.shutdown();
        assert_eq!(report.completed, 12);
        assert!(
            report.mean_batch_size() > 1.0,
            "no batching happened: {}",
            report.mean_batch_size()
        );
    }

    #[test]
    fn str_enabled_configs_batch() {
        // The whole point of the unified stepper: STR (and every other
        // token-reduction mode) no longer forces single-request serving.
        let scfg = ServerConfig { max_batch: 4, queue_depth: 32, ..ServerConfig::default() };
        let fc = FastCacheConfig::with_policy(PolicyKind::FastCache);
        assert!(fc.enable_str, "FastCache default must enable STR");
        let server = Server::start(scfg, fc, || Ok(DitModel::native(Variant::S, 1)));
        let mut rxs = Vec::new();
        for i in 0..12 {
            rxs.push(server.submit(&GenRequest::builder(i, 31 + i).steps(6).build().unwrap()).unwrap());
        }
        for rx in rxs {
            let resp = rx.wait().completed();
            assert!(resp.result.latent.data().iter().all(|v| v.is_finite()));
        }
        let report = server.shutdown();
        assert_eq!(report.completed, 12);
        assert!(
            report.mean_batch_size() > 1.0,
            "STR config did not batch: occupancy {}",
            report.mean_batch_size()
        );
    }

    #[test]
    fn mixed_step_requests_coexist() {
        // Continuous batching admits lanes with different step counts into
        // one active set — no step-alignment grouping anymore.
        let server = test_server(PolicyKind::FastCache, 4, 32);
        let mut rxs = Vec::new();
        for i in 0..4u64 {
            rxs.push((4usize, server.submit(&GenRequest::builder(i, 11 + i).steps(4).build().unwrap()).unwrap()));
            rxs.push((8usize, server.submit(&GenRequest::builder(10 + i, 17 + i).steps(8).build().unwrap()).unwrap()));
        }
        for (steps, rx) in rxs {
            let resp = rx.wait().completed();
            assert_eq!(resp.result.records.len(), steps);
        }
        let report = server.shutdown();
        assert_eq!(report.completed, 8);
        assert!(report.mean_batch_size() > 1.0);
    }

    #[test]
    fn sharded_server_completes_everything_and_merges_reports() {
        let server = test_server_sharded(PolicyKind::FastCache, 2, 32, 3);
        assert_eq!(server.workers(), 3);
        let mut rxs = Vec::new();
        for i in 0..12 {
            rxs.push(server.submit_blocking(&GenRequest::builder(i, 40 + i).steps(4).build().unwrap()).unwrap());
        }
        for rx in rxs {
            let resp = rx.wait().completed();
            assert!(resp.result.latent.data().iter().all(|v| v.is_finite()));
        }
        let report = server.shutdown();
        assert_eq!(report.completed, 12);
        assert_eq!(report.shards.len(), 3);
        let shard_sum: u64 = report.shards.iter().map(|s| s.completed).sum();
        assert_eq!(shard_sum, 12, "per-shard reports must sum to the aggregate");
        // Least-load routing must actually spread a 12-job burst over 3
        // shards rather than piling everything on shard 0.
        let busy = report.shards.iter().filter(|s| s.completed > 0).count();
        assert!(busy >= 2, "burst load never left shard 0");
    }

    #[test]
    fn deadline_jobs_are_admitted_ahead_of_best_effort() {
        // One serial shard: the first job occupies the lane; the next
        // four queue up. The deadline-tagged job is submitted LAST but
        // must be admitted (and so complete) before the queued
        // best-effort jobs.
        let server = test_server(PolicyKind::NoCache, 1, 8);
        let head = server.submit(&GenRequest::builder(0, 1).steps(10).build().unwrap()).unwrap();
        let mut best_effort = Vec::new();
        for i in 1..4u64 {
            best_effort.push(server.submit(&GenRequest::builder(i, 1 + i).steps(4).build().unwrap()).unwrap());
        }
        let tagged = server
            .submit(&GenRequest::builder(9, 9).steps(4).deadline_ms(120_000.0).build().unwrap())
            .unwrap();
        let _ = head.wait();
        let tagged_resp = tagged.wait().completed();
        let be_e2e: Vec<f64> =
            best_effort.into_iter().map(|rx| rx.wait().completed().e2e_ms).collect();
        assert_eq!(tagged_resp.deadline_met, Some(true));
        let max_be = be_e2e.iter().cloned().fold(0.0, f64::max);
        assert!(
            tagged_resp.e2e_ms < max_be,
            "deadline job (submitted last, e2e {:.1} ms) should jump the best-effort \
             queue (max e2e {:.1} ms)",
            tagged_resp.e2e_ms,
            max_be
        );
        let report = server.shutdown();
        assert_eq!(report.deadline_jobs, 1);
        assert_eq!(report.deadline_hits, 1);
        assert_eq!(report.best_effort_jobs, 4);
        assert_eq!(report.deadline_hit_rate(), Some(1.0));
    }

    #[test]
    fn expired_deadline_jobs_are_shed_at_pop_time() {
        // One serial shard busy with a long head job; a deadline-tagged
        // job with an already-expired budget (0 ms) queues behind it. At
        // the next admission boundary the shard must shed it — distinct
        // outcome, counted, never served — while best-effort jobs and the
        // head complete normally.
        let server = test_server(PolicyKind::NoCache, 1, 8);
        let head = server.submit(&GenRequest::builder(0, 1).steps(10).build().unwrap()).unwrap();
        let doomed = server
            .submit(&GenRequest::builder(1, 2).steps(4).deadline_ms(0.0).build().unwrap())
            .unwrap();
        let tail = server.submit(&GenRequest::builder(2, 3).steps(4).build().unwrap()).unwrap();

        match doomed.wait() {
            Outcome::Rejected(rej) => {
                assert_eq!(rej.code, ErrorCode::Expired);
                assert_eq!(rej.id, 1);
                assert_eq!(rej.deadline_ms, 0.0);
                assert!(rej.waited_ms >= 0.0);
            }
            Outcome::Completed(_) => panic!("expired job must be shed, not served"),
        }
        let _ = head.wait().completed();
        let _ = tail.wait().completed();
        let report = server.shutdown();
        assert_eq!(report.completed, 2, "shed jobs are not completions");
        assert_eq!(report.deadline_sheds, 1);
        assert_eq!(report.deadline_jobs, 0, "shed jobs are not served deadline jobs");
        assert_eq!(
            report.deadline_hit_rate(),
            Some(0.0),
            "a shed deadline job is an SLA miss, not a vanished denominator"
        );
        assert_eq!(report.best_effort_jobs, 2);
    }

    #[test]
    fn warm_serving_reuses_fits_across_bursts_and_reports_store_stats() {
        // A caller-owned store shared by two server instances: the first
        // burst publishes (all misses), the second warm-starts from it
        // and must execute fewer FLOPs per step under the confidence
        // gate. This is the tentpole's end-to-end loop at test scale.
        let scfg =
            ServerConfig { max_batch: 4, queue_depth: 16, ..ServerConfig::default() };
        let mut fc = FastCacheConfig::with_policy(PolicyKind::FastCache);
        fc.enable_str = false;
        fc.warm_start = true;
        fc.fit_min_updates = 5;
        fc.tau_delta0 = 1.0;
        let store = std::sync::Arc::new(crate::store::WarmStore::new(
            scfg.warm_budget_bytes,
            scfg.workers,
        ));

        let phase = |expect_warm: bool| -> (f64, u64) {
            // Honor the fingerprint contract: the factory builds with the
            // seed the ServerConfig declares.
            let seed = scfg.weight_seed;
            let server = Server::start_with_store(
                scfg.clone(),
                fc.clone(),
                Some(std::sync::Arc::clone(&store)),
                move || Ok(DitModel::native(Variant::S, seed)),
            );
            let mut rxs = Vec::new();
            for i in 0..4 {
                rxs.push(server.submit(&GenRequest::builder(i, 60 + i).steps(10).build().unwrap()).unwrap());
            }
            let mut flops = 0u64;
            let mut steps = 0usize;
            for rx in rxs {
                let resp = rx.wait().completed();
                flops += resp.result.flops_done;
                steps += resp.result.records.len();
                assert_eq!(resp.result.warm_layers > 0, expect_warm, "warm_layers mismatch");
            }
            let report = server.shutdown();
            let stats = report.store.expect("warm server must report store stats");
            assert!(stats.used_bytes <= stats.budget_bytes);
            if expect_warm {
                assert!(report.warm_admissions > 0);
                assert!(stats.hits > 0, "second burst must hit the store: {stats:?}");
            }
            (flops as f64 / steps as f64, report.warm_admissions)
        };

        let (cold_fps, cold_warm) = phase(false);
        assert_eq!(cold_warm, 0, "empty store cannot warm-start anything");
        let (warm_fps, _) = phase(true);
        assert!(
            warm_fps < cold_fps,
            "warm-started burst must execute fewer FLOPs/step: {warm_fps} vs {cold_fps}"
        );
    }

    /// Serve the same seeded requests through a given server config and
    /// return the latents keyed by submission order.
    fn serve_latents(scfg: ServerConfig) -> Vec<Vec<f32>> {
        let mut fc = FastCacheConfig::with_policy(PolicyKind::FastCache);
        fc.enable_str = false;
        let server = Server::start(scfg, fc, || Ok(DitModel::native(Variant::S, 1)));
        let mut out = Vec::new();
        for i in 0..3u64 {
            let rx = server.submit(&GenRequest::builder(i, 200 + i).steps(4).build().unwrap()).unwrap();
            let resp = rx.wait().completed();
            out.push(resp.result.latent.data().to_vec());
        }
        server.shutdown();
        out
    }

    #[test]
    fn threaded_serving_is_bit_identical_and_reported() {
        // Intra-op threading repartitions rows across scoped workers but
        // never changes any per-row arithmetic, so served latents must be
        // bit-identical whatever thread count the host grants. (On a
        // single-core runner effective_threads clamps to 1 and this
        // degenerates to serial-vs-serial — the kernel-level parity is
        // separately pinned by rust/tests/threaded_parity.rs.)
        let serial = serve_latents(ServerConfig { threads: 1, ..ServerConfig::default() });
        let scfg = ServerConfig { threads: 4, ..ServerConfig::default() };
        let threaded = serve_latents(scfg.clone());
        assert_eq!(serial, threaded, "intra-op threading changed served latents");

        let mut fc = FastCacheConfig::with_policy(PolicyKind::FastCache);
        fc.enable_str = false;
        let server = Server::start(scfg.clone(), fc, || Ok(DitModel::native(Variant::S, 1)));
        let rx = server.submit(&GenRequest::builder(0, 200).steps(4).build().unwrap()).unwrap();
        let _ = rx.wait().completed();
        let report = server.shutdown();
        assert_eq!(report.threads, scfg.effective_threads() as u64);
        assert!(report.threads >= 1);
        assert_eq!(report.shards[0].threads, report.threads);
    }

    #[test]
    fn int8_serving_engages_and_stays_close_to_f32() {
        // `int8: true` must actually route the block matmuls through the
        // quantized panels (outputs differ from f32) without wrecking the
        // latent (bounded relative error after a full denoise).
        let f32_lat = serve_latents(ServerConfig::default());
        let int8_lat = serve_latents(ServerConfig { int8: true, ..ServerConfig::default() });
        let mut max_diff = 0.0f32;
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (a, b) in f32_lat.iter().flatten().zip(int8_lat.iter().flatten()) {
            assert!(b.is_finite(), "int8 serving produced non-finite latent");
            max_diff = max_diff.max((a - b).abs());
            num += f64::from(a - b).powi(2);
            den += f64::from(*a).powi(2);
        }
        assert!(
            max_diff > 0.0,
            "int8 config served bit-identical latents — quantization never engaged"
        );
        let rel = (num / den.max(1e-30)).sqrt();
        assert!(rel < 0.5, "int8 latents drifted too far from f32: rel L2 {rel}");
    }

    #[test]
    fn door_sheds_fold_into_report_and_lower_hit_rate() {
        // One served deadline job gives a perfect 1.0 hit rate; folding
        // in a network door that refused two deadline-tagged requests
        // must drop the rate to 1/3 — refusing at the door is still an
        // SLA miss, never a vanished denominator.
        let server = test_server(PolicyKind::NoCache, 1, 4);
        let rx = server
            .submit(&GenRequest::builder(0, 1).steps(2).deadline_ms(120_000.0).build().unwrap())
            .unwrap();
        assert_eq!(rx.wait().completed().deadline_met, Some(true));
        let mut report = server.shutdown();
        assert_eq!(report.door_sheds, 0);
        assert_eq!(report.net, None);
        assert_eq!(report.deadline_hit_rate(), Some(1.0));

        let stats = NetStats {
            conns_accepted: 3,
            conns_door_shed: 1,
            reqs_submitted: 1,
            reqs_completed: 1,
            reqs_door_shed: 2,
            door_sheds_deadline: 2,
            bytes_in: 64,
            bytes_out: 128,
            ..NetStats::default()
        };
        report.absorb_net(stats.clone());
        assert_eq!(report.door_sheds, 2);
        assert_eq!(report.net, Some(stats));
        assert_eq!(report.deadline_hit_rate(), Some(1.0 / 3.0));
    }

    #[test]
    fn streaming_submissions_deliver_one_progress_tick_per_step() {
        let server = test_server(PolicyKind::NoCache, 1, 4);
        let steps = 4u32;
        let stream = server
            .submit_streaming(&GenRequest::builder(0, 5).steps(steps as usize).build().unwrap())
            .unwrap();
        let mut ticks = 0u32;
        let mut last = 0u32;
        loop {
            match stream.recv_event() {
                Some(Event::Progress(p)) => {
                    ticks += 1;
                    assert_eq!(p.id, 0);
                    assert_eq!(p.total, steps);
                    assert!(p.step > last, "progress must be strictly increasing");
                    last = p.step;
                }
                Some(Event::Done(out)) => {
                    out.completed();
                    break;
                }
                None => panic!("stream ended without a terminal event"),
            }
        }
        assert_eq!(ticks, steps, "one progress tick per denoise step");
        assert_eq!(last, steps, "final tick reads step == total");
        server.shutdown();
    }

    #[test]
    fn non_streaming_submissions_skip_progress() {
        let server = test_server(PolicyKind::NoCache, 1, 4);
        let stream =
            server.submit(&GenRequest::builder(0, 5).steps(3).build().unwrap()).unwrap();
        match stream.recv_event() {
            Some(Event::Done(out)) => {
                out.completed();
            }
            other => panic!("expected only a terminal event, got {other:?}"),
        }
        server.shutdown();
    }

    /// A zeroed per-shard report for merge-arithmetic tests (shards build
    /// theirs by snapshotting live metrics; tests build them directly).
    fn blank_shard(shard: usize) -> ShardReport {
        ShardReport {
            shard,
            completed: 0,
            e2e: LatencyHistogram::new(),
            admission_wait: LatencyHistogram::new(),
            wall_s: 0.0,
            step_calls: 0,
            lane_steps: 0,
            padded_flops: 0,
            deadline_jobs: 0,
            deadline_hits: 0,
            best_effort_jobs: 0,
            deadline_sheds: 0,
            warm_admissions: 0,
            warm_layers: 0,
            scratch_bytes: 0,
            threads: 1,
            internal_errors: 0,
            degraded_lanes: 0,
            degrade_rungs: 0,
            restarts: 0,
            watchdog_sheds: 0,
        }
    }

    #[test]
    fn merge_sums_counters_and_maxes_capacity_fields() {
        let mut a = blank_shard(0);
        a.completed = 3;
        a.step_calls = 10;
        a.lane_steps = 25;
        a.padded_flops = 1_000;
        a.warm_admissions = 2;
        a.warm_layers = 7;
        a.scratch_bytes = 4096;
        a.threads = 2;
        a.e2e.record(10.0);
        a.admission_wait.record(1.0);

        let mut b = blank_shard(1);
        b.completed = 5;
        b.step_calls = 4;
        b.lane_steps = 4;
        b.padded_flops = 500;
        b.warm_admissions = 1;
        b.warm_layers = 3;
        b.scratch_bytes = 8192; // larger arena wins the max
        b.threads = 1;
        b.e2e.record(20.0);
        b.e2e.record(30.0);

        a.internal_errors = 1;
        a.degraded_lanes = 2;
        a.degrade_rungs = 4;
        b.internal_errors = 2;
        b.degrade_rungs = 1;
        a.restarts = 1;
        b.restarts = 2;
        b.watchdog_sheds = 3;

        let r = ServerReport::merge(vec![a, b], 2.5, None);
        assert_eq!(r.completed, 8);
        assert_eq!(r.step_calls, 14);
        assert_eq!(r.lane_steps, 29);
        assert_eq!(r.padded_flops, 1_500);
        assert_eq!(r.warm_admissions, 3);
        assert_eq!(r.warm_layers, 10);
        assert_eq!(r.internal_errors, 3);
        assert_eq!(r.degraded_lanes, 2);
        assert_eq!(r.degrade_rungs, 5);
        assert_eq!(r.shard_restarts, 3);
        assert_eq!(r.watchdog_sheds, 3);
        // Capacity-style fields merge by MAX, not sum: each shard's
        // scratch arena is independent, and threads is a per-shard clamp.
        assert_eq!(r.scratch_bytes, 8192);
        assert_eq!(r.threads, 2);
        assert_eq!(r.wall_s, 2.5);
        assert_eq!(r.e2e.count(), 3);
        assert_eq!(r.admission_wait.count(), 1);
        assert_eq!(r.store, None);
        assert_eq!(r.net, None);
        assert_eq!(r.shards.len(), 2);
        let shard_sum: u64 = r.shards.iter().map(|s| s.completed).sum();
        assert_eq!(shard_sum, r.completed);
    }

    #[test]
    fn hit_rate_counts_queue_and_door_sheds_in_denominator() {
        let mut a = blank_shard(0);
        a.deadline_jobs = 4;
        a.deadline_hits = 3;
        let mut b = blank_shard(1);
        b.deadline_jobs = 2;
        b.deadline_hits = 1;
        b.deadline_sheds = 2; // queue-side sheds: misses, not vanished
        b.best_effort_jobs = 5;

        let mut r = ServerReport::merge(vec![a, b], 1.0, None);
        assert_eq!(r.deadline_jobs, 6);
        assert_eq!(r.deadline_hits, 4);
        assert_eq!(r.deadline_sheds, 2);
        assert_eq!(r.best_effort_jobs, 5);
        // 4 hits / (6 served + 2 shed) — best-effort jobs stay out.
        assert_eq!(r.deadline_hit_rate(), Some(0.5));

        // Door refusals join the denominator on absorb_net.
        r.absorb_net(NetStats { door_sheds_deadline: 2, ..NetStats::default() });
        assert_eq!(r.door_sheds, 2);
        assert_eq!(r.deadline_hit_rate(), Some(0.4));
    }

    #[test]
    fn hit_rate_is_none_without_deadline_traffic() {
        let mut s = blank_shard(0);
        s.best_effort_jobs = 9;
        let r = ServerReport::merge(vec![s], 1.0, None);
        assert_eq!(r.deadline_hit_rate(), None, "best-effort-only traffic has no SLA rate");
        // And the per-shard rate agrees.
        assert_eq!(r.shards[0].deadline_hit_rate(), None);

        // All-shed traffic: denominator exists, rate is a hard 0.
        let mut s = blank_shard(0);
        s.deadline_sheds = 3;
        let r = ServerReport::merge(vec![s], 1.0, None);
        assert_eq!(r.deadline_hit_rate(), Some(0.0));
    }

    #[test]
    fn flight_recorder_never_changes_served_latents() {
        // The tentpole's core invariant: tracing observes decisions, it
        // never makes them. Fixed-seed traffic served with the recorder
        // at full sampling must be BIT-identical to the untraced run.
        let plain = serve_latents(ServerConfig::default());
        let traced =
            serve_latents(ServerConfig { trace_sample_rate: 1.0, ..ServerConfig::default() });
        assert_eq!(plain.len(), traced.len());
        for (p, t) in plain.iter().zip(traced.iter()) {
            assert_eq!(p.len(), t.len());
            for (x, y) in p.iter().zip(t.iter()) {
                assert_eq!(x.to_bits(), y.to_bits(), "tracing perturbed a served latent");
            }
        }
    }

    #[test]
    fn registry_counts_traffic_while_serving() {
        let scfg = ServerConfig {
            max_batch: 2,
            queue_depth: 8,
            trace_sample_rate: 1.0,
            ..ServerConfig::default()
        };
        let mut fc = FastCacheConfig::with_policy(PolicyKind::FastCache);
        fc.enable_str = false;
        let server = Server::start(scfg, fc, || Ok(DitModel::native(Variant::S, 1)));
        let registry = server.registry();
        let recorder = server.recorder().expect("rate 1.0 must attach a recorder");

        let steps = 4usize;
        let n_reqs = 3u64;
        for i in 0..n_reqs {
            let rx =
                server.submit(&GenRequest::builder(i, 300 + i).steps(steps).build().unwrap()).unwrap();
            rx.wait().completed();
        }
        // Live scrape BEFORE shutdown: the registry is readable while the
        // server runs — that is its entire reason to exist.
        let completed: u64 = registry.shards().iter().map(|s| s.completed.get()).sum();
        assert_eq!(completed, n_reqs);
        let dec = registry.decision_totals();
        let layers = crate::config::ModelConfig::of(Variant::S).layers;
        assert_eq!(
            dec.iter().sum::<u64>(),
            n_reqs * steps as u64 * layers as u64,
            "one decision per (lane, step, layer)"
        );
        // At sample rate 1.0 the recorder saw every one of them, and its
        // per-action counts reconcile with the registry's counters.
        assert_eq!(recorder.decision_counts(), dec);

        let report = server.shutdown();
        assert_eq!(report.completed, n_reqs, "shutdown report is the registry's final snapshot");
        assert_eq!(report.step_calls, registry.shards().iter().map(|s| s.step_calls.get()).sum());
    }

    #[test]
    fn unconfigured_faults_and_degrade_leave_serving_bit_identical() {
        let plain = serve_latents(ServerConfig::default());
        // An armed plan whose site can never match (shard 7 of a 1-shard
        // server): the injection hooks run but no fault fires, and serving
        // must be bit-untouched.
        let missed = serve_latents(ServerConfig {
            fault_plan: Some("panic step=1 layer=1 shard=7".to_string()),
            ..ServerConfig::default()
        });
        assert_eq!(plain, missed, "an unfired fault plan changed served latents");
        // Degrade ladder on, but every request is best-effort: the ladder
        // must never silently alter lanes that carry no deadline.
        let degraded = serve_latents(ServerConfig { degrade: true, ..ServerConfig::default() });
        assert_eq!(plain, degraded, "degrade ladder touched best-effort lanes");
        // Supervisor knobs armed but never tripped: a flap threshold with
        // no quarantines, a blocklist with no strikes, and a stall budget
        // no healthy step approaches must all leave serving bit-identical.
        let supervised = serve_latents(ServerConfig {
            shard_restart_after: 3,
            poison_after: 2,
            step_stall_ms: 10_000,
            ..ServerConfig::default()
        });
        assert_eq!(plain, supervised, "an idle supervisor changed served latents");
    }

    #[test]
    fn flapping_kernel_triggers_supervised_restart_and_survivors_match() {
        // Two typed quarantines inside the flap window trip the
        // supervisor: the shard tears down and restarts (fresh stepper,
        // fresh model), replaying survivors at their exact step indices —
        // so the two untouched requests must be BIT-identical to a clean
        // run, and the restart must be visible in the report.
        let run = |plan: Option<&str>, restart_after: usize| {
            let scfg = ServerConfig {
                max_batch: 4,
                queue_depth: 16,
                shard_restart_after: restart_after,
                fault_plan: plan.map(String::from),
                ..ServerConfig::default()
            };
            let mut fc = FastCacheConfig::with_policy(PolicyKind::FastCache);
            fc.enable_str = false;
            let server = Server::start(scfg, fc, || Ok(DitModel::native(Variant::S, 1)));
            let mut rxs = Vec::new();
            for i in 0..4u64 {
                rxs.push(
                    server.submit(&GenRequest::builder(i, 800 + i).steps(4).build().unwrap()).unwrap(),
                );
            }
            let mut outs = Vec::new();
            for rx in rxs {
                match rx.wait() {
                    Outcome::Completed(resp) => outs.push(Some(resp.result.latent.data().to_vec())),
                    Outcome::Rejected(rej) => {
                        assert_eq!(rej.code, ErrorCode::Internal);
                        outs.push(None);
                    }
                }
            }
            (outs, server.shutdown())
        };
        let (clean, clean_report) = run(None, 2);
        assert!(clean.iter().all(Option::is_some));
        assert_eq!(clean_report.shard_restarts, 0, "clean traffic must not restart anything");

        // Two distinct requests panic at consecutive steps — two typed
        // quarantine events on one shard, meeting the flap threshold.
        let (faulted, report) =
            run(Some("panic step=1 layer=0 req=1; panic step=2 layer=0 req=2"), 2);
        assert!(faulted[1].is_none(), "first faulted request must answer Internal");
        assert!(faulted[2].is_none(), "second faulted request must answer Internal");
        for i in [0usize, 3] {
            assert_eq!(
                faulted[i], clean[i],
                "survivor {i} diverged across the supervised restart"
            );
        }
        assert_eq!(report.internal_errors, 2);
        assert_eq!(report.completed, 2);
        assert_eq!(report.shard_restarts, 1, "flap threshold 2 must restart exactly once");

        // Same plan, threshold off: quarantines happen, no restart.
        let (_, unsupervised) =
            run(Some("panic step=1 layer=0 req=1; panic step=2 layer=0 req=2"), 0);
        assert_eq!(unsupervised.shard_restarts, 0, "restart_after=0 must never restart");
        assert_eq!(unsupervised.internal_errors, 2);
    }

    #[test]
    fn watchdog_unsticks_a_stalled_shard_with_honest_shed_accounting() {
        // A seeded stall wedges the head request's step far past the
        // watchdog budget. The watchdog marks the shard unhealthy, sheds
        // its queue honestly (typed Internal, SLA-counted), and escalates
        // to a supervised restart; the head request itself completes once
        // its bounded stall ends.
        let scfg = ServerConfig {
            max_batch: 1,
            queue_depth: 8,
            step_stall_ms: 50,
            fault_plan: Some("stall step=1 ms=800".to_string()),
            ..ServerConfig::default()
        };
        let mut fc = FastCacheConfig::with_policy(PolicyKind::FastCache);
        fc.enable_str = false;
        let server = Server::start(scfg, fc, || Ok(DitModel::native(Variant::S, 1)));
        let head = server.submit(&GenRequest::builder(0, 900).steps(4).build().unwrap()).unwrap();
        // Give the head a beat to occupy the lane before queuing victims,
        // so the stall hits while these jobs wait in the queue.
        std::thread::sleep(std::time::Duration::from_millis(20));
        let queued_be = server.submit(&GenRequest::builder(1, 901).steps(4).build().unwrap()).unwrap();
        let queued_dl = server
            .submit(&GenRequest::builder(2, 902).steps(4).deadline_ms(120_000.0).build().unwrap())
            .unwrap();

        let head_out = head.wait();
        let resp = head_out.completed();
        assert!(
            resp.result.latent.data().iter().all(|v| v.is_finite()),
            "stalled head request must still finish"
        );
        let mut sheds = 0usize;
        for rx in [queued_be, queued_dl] {
            match rx.wait() {
                Outcome::Rejected(rej) => {
                    assert_eq!(rej.code, ErrorCode::Internal);
                    assert!(
                        rej.detail.contains("watchdog"),
                        "shed detail must name the watchdog: {}",
                        rej.detail
                    );
                    sheds += 1;
                }
                Outcome::Completed(_) => {
                    panic!("queued job served from a shard the watchdog declared stuck")
                }
            }
        }
        assert_eq!(sheds, 2, "both queued jobs behind the stall must be shed");
        let report = server.shutdown();
        assert_eq!(report.watchdog_sheds, 2);
        assert!(report.shard_restarts >= 1, "watchdog must escalate to a restart");
        assert_eq!(report.completed, 1, "only the head request completes");
        // The deadline-tagged shed is an SLA miss, never a vanished
        // denominator: one tagged job entered, zero hit.
        assert_eq!(report.deadline_sheds, 1);
        assert_eq!(report.deadline_hit_rate(), Some(0.0));
    }

    #[test]
    fn injected_panic_quarantines_one_lane_and_siblings_match() {
        // The PR's acceptance bar: a kernel panic in one lane of a 4-lane
        // batch answers that request with Internal while the process, the
        // shard, AND the three sibling lanes' exact latents all survive.
        let run = |plan: Option<&str>| {
            let scfg = ServerConfig {
                max_batch: 4,
                queue_depth: 16,
                fault_plan: plan.map(String::from),
                ..ServerConfig::default()
            };
            let mut fc = FastCacheConfig::with_policy(PolicyKind::FastCache);
            fc.enable_str = false;
            let server = Server::start(scfg, fc, || Ok(DitModel::native(Variant::S, 1)));
            let mut rxs = Vec::new();
            for i in 0..4u64 {
                rxs.push(
                    server.submit(&GenRequest::builder(i, 500 + i).steps(4).build().unwrap()).unwrap(),
                );
            }
            let mut outs = Vec::new();
            for rx in rxs {
                match rx.wait() {
                    Outcome::Completed(resp) => {
                        outs.push(Some(resp.result.latent.data().to_vec()));
                    }
                    Outcome::Rejected(rej) => {
                        assert_eq!(rej.code, ErrorCode::Internal);
                        outs.push(None);
                    }
                }
            }
            (outs, server.shutdown())
        };
        let (clean, clean_report) = run(None);
        assert!(clean.iter().all(Option::is_some));
        assert_eq!(clean_report.internal_errors, 0);

        let (faulted, report) = run(Some("panic step=2 layer=1 req=2"));
        assert!(faulted[2].is_none(), "faulted request must answer Internal");
        for i in [0usize, 1, 3] {
            assert_eq!(faulted[i], clean[i], "sibling lane {i} diverged after quarantine");
        }
        assert_eq!(report.internal_errors, 1);
        assert_eq!(report.completed, 3, "a quarantined request is not a completion");
    }

    #[test]
    fn raw_panic_quarantines_the_whole_batch_without_hanging() {
        // An unattributable panic (no FaultPanic payload) cannot name a
        // culprit, so every lane in the stepping batch answers Internal —
        // but the shard survives and keeps serving fresh work.
        let scfg = ServerConfig {
            max_batch: 2,
            queue_depth: 8,
            fault_plan: Some("panic step=1 layer=0 raw=1".to_string()),
            ..ServerConfig::default()
        };
        let mut fc = FastCacheConfig::with_policy(PolicyKind::FastCache);
        fc.enable_str = false;
        let server = Server::start(scfg, fc, || Ok(DitModel::native(Variant::S, 1)));
        let a = server.submit(&GenRequest::builder(0, 600).steps(4).build().unwrap()).unwrap();
        let b = server.submit(&GenRequest::builder(1, 601).steps(4).build().unwrap()).unwrap();
        let outcomes = [a.wait(), b.wait()];
        let internals = outcomes
            .iter()
            .filter(|o| matches!(o, Outcome::Rejected(r) if r.code == ErrorCode::Internal))
            .count();
        // At least the lane that hit step 1 first was quarantined (both,
        // when batch formation won the race — timing decides).
        assert!(internals >= 1, "raw panic produced no Internal rejection");
        for o in &outcomes {
            if let Outcome::Completed(resp) = o {
                assert!(resp.result.latent.data().iter().all(|v| v.is_finite()));
            }
        }
        let c = server.submit(&GenRequest::builder(2, 602).steps(2).build().unwrap()).unwrap();
        let resp = c.wait().completed();
        assert!(resp.result.latent.data().iter().all(|v| v.is_finite()));
        let report = server.shutdown();
        assert_eq!(report.internal_errors as usize, internals);
    }

    #[test]
    fn degrade_ladder_rescues_a_doomed_deadline_lane_with_honest_accounting() {
        let steps = 12usize;
        let serve = |degrade: bool, deadline: Option<f64>| {
            let scfg =
                ServerConfig { max_batch: 1, queue_depth: 4, degrade, ..ServerConfig::default() };
            let mut fc = FastCacheConfig::with_policy(PolicyKind::FastCache);
            fc.enable_str = false;
            let server = Server::start(scfg, fc, || Ok(DitModel::native(Variant::S, 1)));
            // Warm the shard up (model build, scratch arena) so the
            // measured pace and the admission wait reflect steady state.
            let warm = server.submit(&GenRequest::builder(99, 1).steps(1).build().unwrap()).unwrap();
            let _ = warm.wait().completed();
            let mut b = GenRequest::builder(0, 700).steps(steps);
            if let Some(d) = deadline {
                b = b.deadline_ms(d);
            }
            let rx = server.submit(&b.build().unwrap()).unwrap();
            let out = rx.wait();
            (out, server.shutdown())
        };
        // Measure the lane's natural pace best-effort first, then hand the
        // same request a budget a quarter of that — hopeless at full
        // quality, generous enough to survive admission.
        let (baseline, _) = serve(false, None);
        let baseline = baseline.completed();
        let budget = (baseline.e2e_ms / 4.0).max(2.0);

        let (out, report) = serve(true, Some(budget));
        let resp = out.completed();
        assert!(resp.result.degraded, "ladder never engaged under an impossible budget");
        assert!(resp.result.degrade_rungs >= 1);
        assert!(resp.result.records.len() <= steps, "truncation cannot add steps");
        assert!(resp.result.latent.data().iter().all(|v| v.is_finite()));
        // Honest accounting: the verdict is judged on the REAL e2e — a
        // degraded lane is only a hit if it genuinely made its budget.
        assert_eq!(resp.deadline_met, Some(resp.e2e_ms <= budget));
        assert_eq!(report.degraded_lanes, 1);
        assert_eq!(report.degrade_rungs, u64::from(resp.result.degrade_rungs));
        let expected_hits = u64::from(resp.deadline_met == Some(true));
        assert_eq!(report.deadline_hits, expected_hits);
        // Quality delta vs the undegraded run, reported for the record.
        let delta = baseline
            .result
            .latent
            .data()
            .iter()
            .zip(resp.result.latent.data())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        println!("degrade quality delta (max abs vs undegraded): {delta}");
    }
}
