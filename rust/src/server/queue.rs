//! Request/response plumbing for the sharded server: job envelope,
//! per-request outcome types ([`GenOutcome`]: completed vs shed — the
//! shard sheds a queued job whose absolute deadline already expired,
//! see [`Job::expired`]), submission errors, and the bounded per-shard
//! [`JobQueue`] with SLA-aware ordering — deadline-tagged jobs pop ahead
//! of best-effort ones (earliest absolute deadline first), best-effort
//! jobs pop FIFO.

use std::cmp::Ordering;
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::scheduler::{GenRequest, GenResult};

/// What the server returns per request.
#[derive(Debug)]
pub struct GenResponse {
    pub result: GenResult,
    /// Admission latency: submit → lane admitted into the shard's
    /// active set (ms).
    pub queued_ms: f64,
    /// End-to-end latency: submit -> response (ms).
    pub e2e_ms: f64,
    /// For deadline-tagged requests: whether e2e met the deadline.
    /// `None` for best-effort requests.
    pub deadline_met: Option<bool>,
}

/// A shed notice: the job was dropped unserved because its absolute
/// deadline had already passed when the shard went to admit it — running
/// it could only burn compute on a guaranteed SLA miss.
#[derive(Debug, Clone, Copy)]
pub struct ShedNotice {
    pub id: u64,
    /// How long the job sat queued before being shed (ms).
    pub waited_ms: f64,
    /// The deadline budget it could no longer meet (ms from submission).
    pub deadline_ms: f64,
}

/// Per-request outcome delivered on the response channel: served, or shed
/// at the admission boundary. Best-effort jobs (no deadline) are never
/// shed.
#[derive(Debug)]
pub enum GenOutcome {
    Completed(GenResponse),
    Shed(ShedNotice),
}

impl GenOutcome {
    /// The completed response; panics on a shed job (tests and drivers
    /// that know their deadlines are generous).
    pub fn completed(self) -> GenResponse {
        match self {
            GenOutcome::Completed(r) => r,
            GenOutcome::Shed(n) => panic!(
                "request {} was shed after {:.1} ms (deadline {:.1} ms)",
                n.id, n.waited_ms, n.deadline_ms
            ),
        }
    }

    pub fn as_completed(&self) -> Option<&GenResponse> {
        match self {
            GenOutcome::Completed(r) => Some(r),
            GenOutcome::Shed(_) => None,
        }
    }

    pub fn is_shed(&self) -> bool {
        matches!(self, GenOutcome::Shed(_))
    }
}

/// Internal job envelope.
pub struct Job {
    pub req: GenRequest,
    pub resp: mpsc::Sender<GenOutcome>,
    pub submitted: Instant,
    /// Predicted full-compute FLOPs of this job, stamped by the
    /// dispatcher at routing time; the shard subtracts exactly this when
    /// it admits the job, so queued-load accounting cannot drift.
    pub cost: u64,
}

impl Job {
    /// Milliseconds since the request was submitted.
    pub fn waited_ms(&self) -> f64 {
        self.submitted.elapsed().as_secs_f64() * 1e3
    }

    /// Absolute deadline, if the request carries one. Budgets are
    /// clamped to [0, ~31 years]: a non-finite or absurd `deadline_ms`
    /// must not panic `Duration` construction inside the queue lock
    /// (NaN/negative → already expired, +inf → effectively unbounded).
    pub fn deadline(&self) -> Option<Instant> {
        self.req.deadline_ms.map(|ms| {
            const MAX_MS: f64 = 1e12;
            let ms = if ms.is_finite() {
                ms.clamp(0.0, MAX_MS)
            } else if ms > 0.0 {
                MAX_MS
            } else {
                0.0
            };
            self.submitted + Duration::from_secs_f64(ms / 1e3)
        })
    }

    /// Whether the job's absolute deadline has already passed — it can no
    /// longer meet its SLA, so the shard sheds it at pop time instead of
    /// serving a guaranteed miss. Best-effort jobs never expire.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline().is_some_and(|d| d <= now)
    }

    /// Send the shed outcome for this job (consumes it).
    pub fn shed(self) {
        let notice = ShedNotice {
            id: self.req.id,
            waited_ms: self.waited_ms(),
            deadline_ms: self.req.deadline_ms.unwrap_or(0.0),
        };
        let _ = self.resp.send(GenOutcome::Shed(notice));
    }
}

/// Submission failure modes.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Bounded queue is full — caller should back off (backpressure).
    QueueFull,
    /// Server is shutting down.
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "queue full (backpressure)"),
            SubmitError::Closed => write!(f, "server closed"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Outcome of a [`JobQueue::push`]. Rejections hand the job back (boxed —
/// rejection is the rare path) so the dispatcher can retry it on another
/// shard before surfacing backpressure to the caller.
pub enum Push {
    Accepted,
    /// Queue at capacity; the job is returned for rerouting.
    Full(Box<Job>),
    /// Queue closed (shutdown); the job is returned.
    Closed(Box<Job>),
}

struct QueueInner {
    /// (fifo sequence, job) — small per-shard sets, so priority pop is a
    /// linear scan instead of a heap.
    jobs: Vec<(u64, Job)>,
    seq: u64,
    closed: bool,
}

/// Bounded, SLA-aware job queue: one per shard. `push` applies
/// backpressure at `cap`; `pop` returns the highest-priority job —
/// deadline-tagged before best-effort, earliest absolute deadline first,
/// FIFO within a class. After `close`, pushes are rejected but pops drain
/// the remainder (graceful shutdown).
pub struct JobQueue {
    cap: usize,
    inner: Mutex<QueueInner>,
    avail: Condvar,
}

/// Priority order between two queued entries (Less = pops first).
fn priority(a: &(u64, Job), b: &(u64, Job)) -> Ordering {
    match (a.1.deadline(), b.1.deadline()) {
        (Some(da), Some(db)) => da.cmp(&db).then(a.0.cmp(&b.0)),
        (Some(_), None) => Ordering::Less,
        (None, Some(_)) => Ordering::Greater,
        (None, None) => a.0.cmp(&b.0),
    }
}

fn best_index(jobs: &[(u64, Job)]) -> Option<usize> {
    let mut best: Option<usize> = None;
    for (i, cand) in jobs.iter().enumerate() {
        best = match best {
            Some(b) if priority(cand, &jobs[b]) != Ordering::Less => Some(b),
            _ => Some(i),
        };
    }
    best
}

impl JobQueue {
    pub fn new(cap: usize) -> JobQueue {
        JobQueue {
            cap: cap.max(1),
            inner: Mutex::new(QueueInner { jobs: Vec::new(), seq: 0, closed: false }),
            avail: Condvar::new(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.inner.lock().expect("queue poisoned").jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enqueue with backpressure; rejected jobs are handed back.
    pub fn push(&self, job: Job) -> Push {
        let mut inner = self.inner.lock().expect("queue poisoned");
        if inner.closed {
            return Push::Closed(Box::new(job));
        }
        if inner.jobs.len() >= self.cap {
            return Push::Full(Box::new(job));
        }
        let seq = inner.seq;
        inner.seq += 1;
        inner.jobs.push((seq, job));
        drop(inner);
        self.avail.notify_one();
        Push::Accepted
    }

    /// Close the queue: subsequent pushes fail, pops drain what remains.
    pub fn close(&self) {
        self.inner.lock().expect("queue poisoned").closed = true;
        self.avail.notify_all();
    }

    /// Highest-priority job, blocking while the queue is open and empty.
    /// `None` means closed-and-drained — the shard should exit.
    pub fn pop_blocking(&self) -> Option<Job> {
        let mut inner = self.inner.lock().expect("queue poisoned");
        loop {
            if let Some(i) = best_index(&inner.jobs) {
                return Some(inner.jobs.remove(i).1);
            }
            if inner.closed {
                return None;
            }
            inner = self.avail.wait(inner).expect("queue poisoned");
        }
    }

    /// Highest-priority job if one is queued right now (step-boundary
    /// admission while lanes are active must never block).
    pub fn try_pop(&self) -> Option<Job> {
        let mut inner = self.inner.lock().expect("queue poisoned");
        best_index(&inner.jobs).map(|i| inner.jobs.remove(i).1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(id: u64, deadline_ms: Option<f64>) -> (Job, mpsc::Receiver<GenOutcome>) {
        let (tx, rx) = mpsc::channel();
        let mut req = GenRequest::simple(id, id, 2);
        req.deadline_ms = deadline_ms;
        (Job { req, resp: tx, submitted: Instant::now(), cost: 1 }, rx)
    }

    #[test]
    fn bounded_queue_applies_backpressure() {
        let q = JobQueue::new(2);
        let (j0, _r0) = job(0, None);
        let (j1, _r1) = job(1, None);
        let (j2, _r2) = job(2, None);
        assert!(matches!(q.push(j0), Push::Accepted));
        assert!(matches!(q.push(j1), Push::Accepted));
        // Third push bounces AND hands the job back intact.
        match q.push(j2) {
            Push::Full(j) => assert_eq!(j.req.id, 2),
            _ => panic!("expected Full"),
        }
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn closed_queue_rejects_pushes_but_drains_pops() {
        let q = JobQueue::new(4);
        let (j0, _r0) = job(0, None);
        assert!(matches!(q.push(j0), Push::Accepted));
        q.close();
        let (j1, _r1) = job(1, None);
        assert!(matches!(q.push(j1), Push::Closed(_)));
        // The queued job still drains; then the queue reports done.
        assert_eq!(q.pop_blocking().expect("drain").req.id, 0);
        assert!(q.pop_blocking().is_none());
        assert!(q.try_pop().is_none());
    }

    #[test]
    fn deadline_jobs_pop_before_best_effort() {
        let q = JobQueue::new(8);
        let (be0, _a) = job(0, None);
        let (be1, _b) = job(1, None);
        let (late, _c) = job(2, Some(5_000.0));
        let (soon, _d) = job(3, Some(100.0));
        q.push(be0);
        q.push(be1);
        q.push(late);
        q.push(soon);
        // Deadline class first (earliest absolute deadline), then FIFO.
        assert_eq!(q.pop_blocking().unwrap().req.id, 3);
        assert_eq!(q.pop_blocking().unwrap().req.id, 2);
        assert_eq!(q.pop_blocking().unwrap().req.id, 0);
        assert_eq!(q.pop_blocking().unwrap().req.id, 1);
    }

    #[test]
    fn non_finite_deadlines_clamp_instead_of_panicking() {
        let q = JobQueue::new(4);
        let (inf_j, _a) = job(0, Some(f64::INFINITY));
        let (nan_j, _b) = job(1, Some(f64::NAN));
        let (soon, _c) = job(2, Some(10.0));
        q.push(inf_j);
        q.push(nan_j);
        q.push(soon);
        // NaN clamps to already-expired (earliest deadline, pops first);
        // +inf clamps to the far future (pops last of the tagged class).
        assert_eq!(q.pop_blocking().unwrap().req.id, 1);
        assert_eq!(q.pop_blocking().unwrap().req.id, 2);
        assert_eq!(q.pop_blocking().unwrap().req.id, 0);
    }

    #[test]
    fn try_pop_never_blocks() {
        let q = JobQueue::new(1);
        assert!(q.try_pop().is_none());
        let (j, _r) = job(7, None);
        q.push(j);
        assert_eq!(q.try_pop().unwrap().req.id, 7);
    }

    #[test]
    fn expiry_predicate_and_shed_notice() {
        let now = Instant::now();
        // Already-expired budget (0 ms), live budget, best-effort.
        let (dead, rx) = job(1, Some(0.0));
        let (live, _a) = job(2, Some(60_000.0));
        let (be, _b) = job(3, None);
        assert!(dead.expired(now + Duration::from_millis(1)));
        assert!(!live.expired(now));
        assert!(!be.expired(now + Duration::from_secs(3600)), "best-effort never expires");
        dead.shed();
        match rx.recv().unwrap() {
            GenOutcome::Shed(n) => {
                assert_eq!(n.id, 1);
                assert_eq!(n.deadline_ms, 0.0);
                assert!(n.waited_ms >= 0.0);
            }
            GenOutcome::Completed(_) => panic!("expected a shed outcome"),
        }
    }

    #[test]
    fn outcome_accessors_distinguish_shed() {
        let shed = GenOutcome::Shed(ShedNotice { id: 9, waited_ms: 1.0, deadline_ms: 2.0 });
        assert!(shed.is_shed());
        assert!(shed.as_completed().is_none());
    }
}
