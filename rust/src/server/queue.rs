//! Request plumbing for the sharded server: the job envelope and the
//! bounded per-shard [`JobQueue`] with SLA-aware ordering —
//! deadline-tagged jobs pop ahead of best-effort ones (earliest absolute
//! deadline first), best-effort jobs pop FIFO.
//!
//! Response types live in [`crate::api`] (ONE vocabulary for the
//! in-process and network transports): a job's channel carries
//! [`Event`]s — optional progress ticks, then exactly one terminal
//! [`Outcome`]. A shard sheds a queued job whose absolute deadline has
//! already expired (see [`Job::expired`]) by sending
//! `Outcome::Rejected(Reject::expired(..))`.

use std::cmp::Ordering;
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::api::{Event, Outcome, Reject};
use crate::scheduler::GenRequest;

/// Internal job envelope.
pub struct Job {
    pub req: GenRequest,
    pub resp: mpsc::Sender<Event>,
    pub submitted: Instant,
    /// Predicted full-compute FLOPs of this job, stamped by the
    /// dispatcher at routing time; the shard subtracts exactly this when
    /// it admits the job, so queued-load accounting cannot drift.
    pub cost: u64,
    /// Whether the caller asked for per-step [`Event::Progress`] ticks
    /// (streaming submissions). Non-streaming jobs get the terminal
    /// event only.
    pub progress: bool,
}

impl Job {
    /// Milliseconds since the request was submitted.
    pub fn waited_ms(&self) -> f64 {
        self.submitted.elapsed().as_secs_f64() * 1e3
    }

    /// Absolute deadline, if the request carries one. Budgets are
    /// clamped to [0, ~31 years]: a non-finite or absurd `deadline_ms`
    /// must not panic `Duration` construction inside the queue lock
    /// (NaN/negative → already expired, +inf → effectively unbounded).
    pub fn deadline(&self) -> Option<Instant> {
        self.req.deadline_ms.map(|ms| {
            const MAX_MS: f64 = 1e12;
            let ms = if ms.is_finite() {
                ms.clamp(0.0, MAX_MS)
            } else if ms > 0.0 {
                MAX_MS
            } else {
                0.0
            };
            self.submitted + Duration::from_secs_f64(ms / 1e3)
        })
    }

    /// Whether the job's absolute deadline has already passed — it can no
    /// longer meet its SLA, so the shard sheds it at pop time instead of
    /// serving a guaranteed miss. Best-effort jobs never expire.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline().is_some_and(|d| d <= now)
    }

    /// Send the shed outcome for this job (consumes it): a typed
    /// `Expired` rejection carrying how long it waited and the budget it
    /// could no longer meet.
    pub fn shed(self) {
        let rej =
            Reject::expired(self.req.id, self.waited_ms(), self.req.deadline_ms.unwrap_or(0.0));
        let _ = self.resp.send(Event::Done(Outcome::Rejected(rej)));
    }
}

/// Outcome of a [`JobQueue::push`]. Rejections hand the job back (boxed —
/// rejection is the rare path) so the dispatcher can retry it on another
/// shard before surfacing backpressure to the caller.
pub enum Push {
    Accepted,
    /// Queue at capacity; the job is returned for rerouting.
    Full(Box<Job>),
    /// Queue closed (shutdown); the job is returned.
    Closed(Box<Job>),
}

struct QueueInner {
    /// (fifo sequence, job) — small per-shard sets, so priority pop is a
    /// linear scan instead of a heap.
    jobs: Vec<(u64, Job)>,
    seq: u64,
    closed: bool,
}

/// Bounded, SLA-aware job queue: one per shard. `push` applies
/// backpressure at `cap`; `pop` returns the highest-priority job —
/// deadline-tagged before best-effort, earliest absolute deadline first,
/// FIFO within a class. After `close`, pushes are rejected but pops drain
/// the remainder (graceful shutdown).
pub struct JobQueue {
    cap: usize,
    inner: Mutex<QueueInner>,
    avail: Condvar,
}

/// Priority order between two queued entries (Less = pops first).
fn priority(a: &(u64, Job), b: &(u64, Job)) -> Ordering {
    match (a.1.deadline(), b.1.deadline()) {
        (Some(da), Some(db)) => da.cmp(&db).then(a.0.cmp(&b.0)),
        (Some(_), None) => Ordering::Less,
        (None, Some(_)) => Ordering::Greater,
        (None, None) => a.0.cmp(&b.0),
    }
}

fn best_index(jobs: &[(u64, Job)]) -> Option<usize> {
    let mut best: Option<usize> = None;
    for (i, cand) in jobs.iter().enumerate() {
        best = match best {
            Some(b) if priority(cand, &jobs[b]) != Ordering::Less => Some(b),
            _ => Some(i),
        };
    }
    best
}

impl JobQueue {
    pub fn new(cap: usize) -> JobQueue {
        JobQueue {
            cap: cap.max(1),
            inner: Mutex::new(QueueInner { jobs: Vec::new(), seq: 0, closed: false }),
            avail: Condvar::new(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.inner.lock().expect("queue poisoned").jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enqueue with backpressure; rejected jobs are handed back.
    pub fn push(&self, job: Job) -> Push {
        let mut inner = self.inner.lock().expect("queue poisoned");
        if inner.closed {
            return Push::Closed(Box::new(job));
        }
        if inner.jobs.len() >= self.cap {
            return Push::Full(Box::new(job));
        }
        let seq = inner.seq;
        inner.seq += 1;
        inner.jobs.push((seq, job));
        drop(inner);
        self.avail.notify_one();
        Push::Accepted
    }

    /// Close the queue: subsequent pushes fail, pops drain what remains.
    pub fn close(&self) {
        self.inner.lock().expect("queue poisoned").closed = true;
        self.avail.notify_all();
    }

    /// Highest-priority job, blocking while the queue is open and empty.
    /// `None` means closed-and-drained — the shard should exit.
    pub fn pop_blocking(&self) -> Option<Job> {
        let mut inner = self.inner.lock().expect("queue poisoned");
        loop {
            if let Some(i) = best_index(&inner.jobs) {
                return Some(inner.jobs.remove(i).1);
            }
            if inner.closed {
                return None;
            }
            inner = self.avail.wait(inner).expect("queue poisoned");
        }
    }

    /// Highest-priority job if one is queued right now (step-boundary
    /// admission while lanes are active must never block).
    pub fn try_pop(&self) -> Option<Job> {
        let mut inner = self.inner.lock().expect("queue poisoned");
        best_index(&inner.jobs).map(|i| inner.jobs.remove(i).1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::ErrorCode;

    fn job(id: u64, deadline_ms: Option<f64>) -> (Job, mpsc::Receiver<Event>) {
        let (tx, rx) = mpsc::channel();
        let mut req = GenRequest::builder(id, id).steps(2).build().unwrap();
        req.deadline_ms = deadline_ms;
        (Job { req, resp: tx, submitted: Instant::now(), cost: 1, progress: false }, rx)
    }

    #[test]
    fn bounded_queue_applies_backpressure() {
        let q = JobQueue::new(2);
        let (j0, _r0) = job(0, None);
        let (j1, _r1) = job(1, None);
        let (j2, _r2) = job(2, None);
        assert!(matches!(q.push(j0), Push::Accepted));
        assert!(matches!(q.push(j1), Push::Accepted));
        // Third push bounces AND hands the job back intact.
        match q.push(j2) {
            Push::Full(j) => assert_eq!(j.req.id, 2),
            _ => panic!("expected Full"),
        }
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn closed_queue_rejects_pushes_but_drains_pops() {
        let q = JobQueue::new(4);
        let (j0, _r0) = job(0, None);
        assert!(matches!(q.push(j0), Push::Accepted));
        q.close();
        let (j1, _r1) = job(1, None);
        assert!(matches!(q.push(j1), Push::Closed(_)));
        // The queued job still drains; then the queue reports done.
        assert_eq!(q.pop_blocking().expect("drain").req.id, 0);
        assert!(q.pop_blocking().is_none());
        assert!(q.try_pop().is_none());
    }

    #[test]
    fn deadline_jobs_pop_before_best_effort() {
        let q = JobQueue::new(8);
        let (be0, _a) = job(0, None);
        let (be1, _b) = job(1, None);
        let (late, _c) = job(2, Some(5_000.0));
        let (soon, _d) = job(3, Some(100.0));
        q.push(be0);
        q.push(be1);
        q.push(late);
        q.push(soon);
        // Deadline class first (earliest absolute deadline), then FIFO.
        assert_eq!(q.pop_blocking().unwrap().req.id, 3);
        assert_eq!(q.pop_blocking().unwrap().req.id, 2);
        assert_eq!(q.pop_blocking().unwrap().req.id, 0);
        assert_eq!(q.pop_blocking().unwrap().req.id, 1);
    }

    #[test]
    fn non_finite_deadlines_clamp_instead_of_panicking() {
        let q = JobQueue::new(4);
        let (inf_j, _a) = job(0, Some(f64::INFINITY));
        let (nan_j, _b) = job(1, Some(f64::NAN));
        let (soon, _c) = job(2, Some(10.0));
        q.push(inf_j);
        q.push(nan_j);
        q.push(soon);
        // NaN clamps to already-expired (earliest deadline, pops first);
        // +inf clamps to the far future (pops last of the tagged class).
        assert_eq!(q.pop_blocking().unwrap().req.id, 1);
        assert_eq!(q.pop_blocking().unwrap().req.id, 2);
        assert_eq!(q.pop_blocking().unwrap().req.id, 0);
    }

    #[test]
    fn try_pop_never_blocks() {
        let q = JobQueue::new(1);
        assert!(q.try_pop().is_none());
        let (j, _r) = job(7, None);
        q.push(j);
        assert_eq!(q.try_pop().unwrap().req.id, 7);
    }

    #[test]
    fn expiry_predicate_and_shed_rejection() {
        let now = Instant::now();
        // Already-expired budget (0 ms), live budget, best-effort.
        let (dead, rx) = job(1, Some(0.0));
        let (live, _a) = job(2, Some(60_000.0));
        let (be, _b) = job(3, None);
        assert!(dead.expired(now + Duration::from_millis(1)));
        assert!(!live.expired(now));
        assert!(!be.expired(now + Duration::from_secs(3600)), "best-effort never expires");
        dead.shed();
        match rx.recv().unwrap() {
            Event::Done(Outcome::Rejected(rej)) => {
                assert_eq!(rej.code, ErrorCode::Expired);
                assert_eq!(rej.id, 1);
                assert_eq!(rej.deadline_ms, 0.0);
                assert!(rej.waited_ms >= 0.0);
            }
            other => panic!("expected an expired rejection, got {other:?}"),
        }
    }
}
