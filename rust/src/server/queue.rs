//! Request/response plumbing: job envelope, response type, and submission
//! errors (bounded-queue backpressure).

use std::sync::mpsc;
use std::time::Instant;

use crate::scheduler::{GenRequest, GenResult};

/// What the server returns per request.
#[derive(Debug)]
pub struct GenResponse {
    pub result: GenResult,
    /// Admission latency: submit → lane admitted into the worker's
    /// active set (ms).
    pub queued_ms: f64,
    /// End-to-end latency: submit -> response (ms).
    pub e2e_ms: f64,
}

/// Internal job envelope.
pub struct Job {
    pub req: GenRequest,
    pub resp: mpsc::Sender<GenResponse>,
    pub submitted: Instant,
}

impl Job {
    /// Milliseconds since the request was submitted.
    pub fn waited_ms(&self) -> f64 {
        self.submitted.elapsed().as_secs_f64() * 1e3
    }
}

/// Submission failure modes.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Bounded queue is full — caller should back off (backpressure).
    QueueFull,
    /// Server is shutting down.
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "queue full (backpressure)"),
            SubmitError::Closed => write!(f, "server closed"),
        }
    }
}

impl std::error::Error for SubmitError {}
