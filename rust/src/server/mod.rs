//! Serving layer: bounded request queue with backpressure, a
//! continuous-batching worker over the unified lane stepper (lanes at
//! different steps coexist; admission happens at step boundaries), and
//! per-server metrics including occupancy and admission latency.
//!
//! Threading note: tokio is not vendored in the offline registry, so the
//! server uses std threads + channels. On the single-core CPU testbed this
//! is also the faithful design — one PJRT worker saturates the core; the
//! queue provides admission control and batching the way an async runtime
//! would.

pub mod queue;
pub mod worker;

pub use queue::{GenResponse, Job};
pub use worker::{Server, ServerReport};
