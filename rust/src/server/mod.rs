//! Serving layer: sharded dispatch over bounded SLA-aware request queues,
//! with one continuous-batching worker (the unified lane stepper) per
//! shard and per-server metrics merged across shards.
//!
//! Layout:
//! - `queue`    — job envelope, bounded per-shard [`queue::JobQueue`]
//!   (backpressure + deadline-first pop order). Response/outcome types
//!   live in [`crate::api`] — ONE vocabulary shared with the network
//!   front door (`crate::net`).
//! - `worker`   — the shard serve loop (continuous batching, SLA-aware
//!   admission at step boundaries, expired-deadline shedding, warm-start
//!   adopt/publish hooks), `ShardReport`/`ServerReport`, and the public
//!   [`Server`] façade.
//! - `dispatch` — spawns `ServerConfig.workers` shard threads, routes
//!   each job to the shard with the least *predicted* remaining FLOPs
//!   (cache-policy-aware, see `Lane::remaining_flops_estimate`), and
//!   threads the shared `store::WarmStore` to every shard.
//! - `supervisor` — the self-healing layer: per-shard flap control with
//!   supervised restarts, the poisoned-request blocklist consulted at
//!   admission, step heartbeats, and the health states the stuck-step
//!   watchdog and the wire `Health` frame read.
//!
//! Threading note: tokio is not vendored in the offline registry, so the
//! server uses std threads + mutex/condvar queues. Each shard owns its
//! own model instance (PJRT clients are not shared across threads; the
//! `Arc`-shared factory is seed-deterministic so all shards serve
//! identical weights), while the `ScheduleCache` is shared across shards.

pub mod dispatch;
pub mod queue;
pub mod supervisor;
pub mod worker;

pub use dispatch::{Dispatcher, ShardLoad};
pub use queue::{Job, JobQueue};
pub use supervisor::{HealthSnapshot, HealthState, Supervisor};
pub use worker::{Server, ServerReport, ShardReport};

// Response-side types moved to `crate::api` in the front-door redesign;
// re-exported here so `server::GenResponse`-style paths keep working.
pub use crate::api::{Event, GenResponse, Outcome, Reject};
