//! Shard supervisor: the self-healing layer over the fault-contained
//! serving loop.
//!
//! PR-9 taught a shard to QUARANTINE a poisoned lane — answer it
//! `Internal`, rebuild the stepper, solo-replay the survivors — and keep
//! serving. That containment is the right first response, but it leaves
//! three failure shapes unhandled, and this module closes each one:
//!
//! - **Flapping**: a shard that quarantines over and over (a bad weight
//!   block, a corrupted arena, an overheating core) burns its batch's
//!   latency budget on endless replays. The supervisor tracks quarantine
//!   events per shard in a sliding [`FLAP_WINDOW`]; past
//!   `--shard-restart-after N` it tears the shard down and restarts it
//!   cleanly — fresh stepper, fresh arena, freshly built model — with
//!   surviving lanes re-admitted at their exact step indices through the
//!   same solo-replay path (so the batched-equals-solo invariant keeps
//!   the restart bit-exact for survivors).
//! - **Poison pills**: a request whose lane keeps triggering TYPED
//!   quarantines will poison every shard it lands on. After
//!   `--poison-after K` strikes its req_id goes on a byte-bounded
//!   blocklist ([`LruBytes`], so an adversarial id stream cannot grow
//!   memory) and is refused at ADMISSION — in-process and at the net
//!   door, which funnel through the same dispatcher gate — with
//!   [`ErrorCode::Poisoned`](crate::api::ErrorCode). Deadline-tagged
//!   rejections still count against the SLA: refusing work is an answer,
//!   not an excuse.
//! - **Wedged (not panicking) kernels**: a stuck step never unwinds, so
//!   `catch_unwind` never fires. Every `step()` call bumps a relaxed
//!   per-shard heartbeat; the watchdog thread (armed by
//!   `--step-stall-ms`) watches for a heartbeat that stops advancing
//!   while lanes are active, marks the shard [`HealthState::Unhealthy`],
//!   sheds its queue honestly (deadline sheds count as misses), and
//!   escalates to a supervised restart once the wedged step returns.
//!
//! Invariant: **restarts are never silent**. Every restart, blocklist
//! insertion, and watchdog shed is visible in the registry
//! (`shard.restarts`, `supervisor.*`, `server.watchdog_sheds`), in the
//! shutdown `ServerReport`, and over the wire in the `HealthReply`
//! frame — which is answered even while draining, because liveness
//! questions deserve answers exactly when the server is sickest.
//!
//! The supervisor is ALWAYS constructed (so `health` works on an
//! unconfigured server) but is inert with all knobs at 0: it then only
//! counts heartbeats and reports `Healthy`, and serving stays
//! bit-identical to a supervisor-less build.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::config::ServerConfig;
use crate::store::{ByteSized, LruBytes};

/// Sliding window over which quarantine events count toward the flap
/// threshold. Events older than this no longer argue for a restart.
pub const FLAP_WINDOW: Duration = Duration::from_secs(30);

/// Byte budget for the poisoned-request blocklist. Strikes are tiny
/// (u32 + entry overhead), so this holds ~600 distinct offender ids —
/// far more than any sane workload produces — while an adversarial
/// stream of fresh req_ids evicts old strikes instead of growing memory.
pub const BLOCKLIST_BUDGET_BYTES: usize = 64 * 1024;

/// One shard's health, as reported on the wire and in the registry.
/// Discriminants are the wire encoding (PROTOCOL.md v4) — append-only.
#[repr(u8)]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HealthState {
    /// Serving normally.
    Healthy = 0,
    /// At least one quarantine inside the flap window, below threshold.
    Degraded = 1,
    /// Supervised teardown + survivor replay in progress.
    Restarting = 2,
    /// Watchdog-flagged stall: heartbeat stopped with lanes active.
    Unhealthy = 3,
}

impl HealthState {
    pub fn from_code(v: u8) -> HealthState {
        match v {
            1 => HealthState::Degraded,
            2 => HealthState::Restarting,
            3 => HealthState::Unhealthy,
            _ => HealthState::Healthy,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            HealthState::Healthy => "healthy",
            HealthState::Degraded => "degraded",
            HealthState::Restarting => "restarting",
            HealthState::Unhealthy => "unhealthy",
        }
    }
}

/// One liveness observation of a running server: what the in-process
/// `Server::health_snapshot` returns and the wire `HealthReply` frame
/// carries (the net door adds its own drain flag on top).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HealthSnapshot {
    /// Per-shard health, indexed by shard id.
    pub states: Vec<HealthState>,
    /// Supervised restarts, summed over shards.
    pub restarts: u64,
    /// Distinct request ids ever blocklisted.
    pub blocklisted: u64,
}

/// Strike count for one request id on the blocklist.
struct PoisonEntry {
    strikes: u32,
}

impl ByteSized for PoisonEntry {
    fn size_bytes(&self) -> usize {
        std::mem::size_of::<u32>()
    }
}

/// Per-shard supervised state. The heartbeat is bumped by the shard
/// thread on EVERY `step()` call with one relaxed add — cheap enough to
/// leave on unconditionally, and observation never shapes serving.
struct ShardHealth {
    state: AtomicU8,
    heartbeat: AtomicU64,
    restart_requested: AtomicBool,
    /// Quarantine instants inside the flap window (pruned on record).
    window: Mutex<VecDeque<Instant>>,
}

impl ShardHealth {
    fn new() -> ShardHealth {
        ShardHealth {
            state: AtomicU8::new(HealthState::Healthy as u8),
            heartbeat: AtomicU64::new(0),
            restart_requested: AtomicBool::new(false),
            window: Mutex::new(VecDeque::new()),
        }
    }
}

/// The supervisor: flap control, poisoned-request blocklist, and the
/// heartbeat/health surface the watchdog and the `Health` frame read.
/// One per server, shared as an `Arc` by the dispatcher, every shard
/// thread, the watchdog, the registry, and the net door.
pub struct Supervisor {
    restart_after: usize,
    poison_after: usize,
    stall_ms: u64,
    shards: Vec<ShardHealth>,
    blocklist: Mutex<LruBytes<u64, PoisonEntry>>,
    blocklisted_total: AtomicU64,
    poisoned_rejections: AtomicU64,
    poisoned_sheds: AtomicU64,
}

impl Supervisor {
    pub fn new(n_shards: usize, scfg: &ServerConfig) -> Supervisor {
        Supervisor {
            restart_after: scfg.shard_restart_after,
            poison_after: scfg.poison_after,
            stall_ms: scfg.step_stall_ms,
            shards: (0..n_shards).map(|_| ShardHealth::new()).collect(),
            blocklist: Mutex::new(LruBytes::new(BLOCKLIST_BUDGET_BYTES)),
            blocklisted_total: AtomicU64::new(0),
            poisoned_rejections: AtomicU64::new(0),
            poisoned_sheds: AtomicU64::new(0),
        }
    }

    pub fn restart_after(&self) -> usize {
        self.restart_after
    }

    pub fn poison_after(&self) -> usize {
        self.poison_after
    }

    pub fn stall_ms(&self) -> u64 {
        self.stall_ms
    }

    // ---- heartbeats -----------------------------------------------------

    /// Bump the shard's step heartbeat (called before every `step()`).
    pub fn beat(&self, shard: usize) {
        self.shards[shard].heartbeat.fetch_add(1, Ordering::Relaxed);
    }

    pub fn heartbeat(&self, shard: usize) -> u64 {
        self.shards[shard].heartbeat.load(Ordering::Relaxed)
    }

    // ---- health states --------------------------------------------------

    pub fn state(&self, shard: usize) -> HealthState {
        HealthState::from_code(self.shards[shard].state.load(Ordering::Relaxed))
    }

    pub fn set_state(&self, shard: usize, state: HealthState) {
        self.shards[shard].state.store(state as u8, Ordering::Relaxed);
    }

    pub fn states(&self) -> Vec<HealthState> {
        (0..self.shards.len()).map(|i| self.state(i)).collect()
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    // ---- flap control ---------------------------------------------------

    /// Record one quarantine event on `shard`. `req_id` is the offender
    /// for TYPED faults (a `FaultPanic` attributed to one lane) and
    /// `None` for untyped batch quarantines — only attributed faults
    /// file a blocklist strike, because an unattributed panic must not
    /// blocklist innocent batch-mates. Returns `true` when the flap
    /// threshold is reached and the caller (the shard thread, which owns
    /// its stepper) must perform a supervised restart.
    pub fn record_quarantine(&self, shard: usize, req_id: Option<u64>) -> bool {
        if let Some(id) = req_id {
            self.note_strike(id);
        }
        let now = Instant::now();
        let mut window = self.shards[shard].window.lock().expect("flap window poisoned");
        window.push_back(now);
        while window.front().is_some_and(|t| now.duration_since(*t) > FLAP_WINDOW) {
            window.pop_front();
        }
        let flapping = self.restart_after > 0 && window.len() >= self.restart_after;
        if flapping {
            // The restart resets the evidence: a post-restart quarantine
            // starts a fresh case against the (now fresh) shard.
            window.clear();
        }
        drop(window);
        self.set_state(
            shard,
            if flapping { HealthState::Restarting } else { HealthState::Degraded },
        );
        flapping
    }

    /// Quarantine events currently inside the flap window (diagnostics).
    pub fn flap_count(&self, shard: usize) -> usize {
        self.shards[shard].window.lock().expect("flap window poisoned").len()
    }

    /// Mark a supervised restart complete: the shard is fresh, so its
    /// health and flap history reset.
    pub fn finish_restart(&self, shard: usize) {
        self.shards[shard].window.lock().expect("flap window poisoned").clear();
        self.shards[shard].restart_requested.store(false, Ordering::Relaxed);
        self.set_state(shard, HealthState::Healthy);
    }

    // ---- watchdog escalation --------------------------------------------

    /// Watchdog: ask the shard thread to restart at its next loop
    /// iteration (it owns the stepper; nobody else can rebuild it).
    pub fn request_restart(&self, shard: usize) {
        self.shards[shard].restart_requested.store(true, Ordering::Relaxed);
    }

    /// Shard thread: consume a pending restart request, if any.
    pub fn take_restart_request(&self, shard: usize) -> bool {
        self.shards[shard].restart_requested.swap(false, Ordering::Relaxed)
    }

    // ---- poisoned-request blocklist -------------------------------------

    /// File one strike against `req_id`. Crossing the `poison_after`
    /// threshold counts a blocklist insertion (once per crossing).
    fn note_strike(&self, req_id: u64) {
        if self.poison_after == 0 {
            return;
        }
        let mut bl = self.blocklist.lock().expect("blocklist poisoned");
        let strikes = bl.peek(&req_id).map_or(0, |e| e.strikes).saturating_add(1);
        bl.insert(req_id, PoisonEntry { strikes });
        if strikes as usize == self.poison_after {
            self.blocklisted_total.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Admission gate: is this request id blocklisted? Refreshes the
    /// entry's recency so active offenders stay resident.
    pub fn is_poisoned(&self, req_id: u64) -> bool {
        if self.poison_after == 0 {
            return false;
        }
        let mut bl = self.blocklist.lock().expect("blocklist poisoned");
        bl.get(&req_id).is_some_and(|e| e.strikes as usize >= self.poison_after)
    }

    /// Count one admission-time `Poisoned` rejection (`deadline`: the
    /// request carried an SLA budget, so the rejection is an SLA miss).
    pub fn note_poisoned_rejection(&self, deadline: bool) {
        self.poisoned_rejections.fetch_add(1, Ordering::Relaxed);
        if deadline {
            self.poisoned_sheds.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Distinct request ids that have ever crossed the strike threshold.
    pub fn blocklisted(&self) -> u64 {
        self.blocklisted_total.load(Ordering::Relaxed)
    }

    /// Requests refused at admission with `ErrorCode::Poisoned`.
    pub fn poisoned_rejections(&self) -> u64 {
        self.poisoned_rejections.load(Ordering::Relaxed)
    }

    /// The deadline-tagged subset of those rejections (SLA misses).
    pub fn poisoned_sheds(&self) -> u64 {
        self.poisoned_sheds.load(Ordering::Relaxed)
    }
}

impl std::fmt::Debug for Supervisor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Supervisor")
            .field("restart_after", &self.restart_after)
            .field("poison_after", &self.poison_after)
            .field("stall_ms", &self.stall_ms)
            .field("shards", &self.shards.len())
            .field("states", &self.states())
            .field("blocklisted", &self.blocklisted())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sup(restart_after: usize, poison_after: usize) -> Supervisor {
        let scfg = ServerConfig {
            shard_restart_after: restart_after,
            poison_after,
            ..ServerConfig::default()
        };
        Supervisor::new(2, &scfg)
    }

    #[test]
    fn inert_with_default_knobs() {
        let s = sup(0, 0);
        assert!(!s.record_quarantine(0, Some(42)), "restart_after=0 never asks for a restart");
        assert!(!s.record_quarantine(0, Some(42)));
        assert!(!s.is_poisoned(42), "poison_after=0 never blocklists");
        assert_eq!(s.blocklisted(), 0);
        // Quarantines still degrade health — visibility stays on even
        // when the self-healing actions are off.
        assert_eq!(s.state(0), HealthState::Degraded);
        assert_eq!(s.state(1), HealthState::Healthy);
    }

    #[test]
    fn flap_threshold_requests_restart_and_resets_window() {
        let s = sup(3, 0);
        assert!(!s.record_quarantine(0, Some(1)));
        // Untyped batch quarantines count toward the flap too.
        assert!(!s.record_quarantine(0, None));
        assert_eq!(s.state(0), HealthState::Degraded);
        assert_eq!(s.flap_count(0), 2);
        assert!(s.record_quarantine(0, Some(3)), "third quarantine in the window trips the flap");
        assert_eq!(s.state(0), HealthState::Restarting);
        assert_eq!(s.flap_count(0), 0, "tripping the threshold resets the evidence");
        s.finish_restart(0);
        assert_eq!(s.state(0), HealthState::Healthy);
        // A fresh case builds from zero; shard 1's window is independent.
        assert!(!s.record_quarantine(0, Some(4)));
        assert!(!s.record_quarantine(1, Some(5)));
        assert_eq!(s.flap_count(1), 1);
    }

    #[test]
    fn strikes_blocklist_a_request_after_k_typed_quarantines() {
        let s = sup(0, 2);
        assert!(!s.is_poisoned(7));
        s.record_quarantine(0, Some(7));
        assert!(!s.is_poisoned(7), "one strike is not enough");
        s.record_quarantine(1, Some(7));
        assert!(s.is_poisoned(7), "second strike blocklists the id");
        assert_eq!(s.blocklisted(), 1);
        // Further strikes don't re-count the insertion.
        s.record_quarantine(0, Some(7));
        assert_eq!(s.blocklisted(), 1);
        // Unattributed quarantines never strike anyone.
        s.record_quarantine(0, None);
        assert!(!s.is_poisoned(0));
        // Rejection accounting separates SLA misses from best-effort.
        s.note_poisoned_rejection(true);
        s.note_poisoned_rejection(false);
        assert_eq!(s.poisoned_rejections(), 2);
        assert_eq!(s.poisoned_sheds(), 1);
    }

    #[test]
    fn blocklist_is_byte_bounded() {
        let s = sup(0, 1);
        // Far more distinct offender ids than the budget holds: memory
        // must stay bounded (LRU eviction), not grow without limit.
        for id in 0..10_000u64 {
            s.record_quarantine(0, Some(id));
        }
        let bl = s.blocklist.lock().unwrap();
        assert!(bl.used_bytes() <= BLOCKLIST_BUDGET_BYTES);
        assert!(bl.len() < 1000, "entries evict instead of accumulating");
    }

    #[test]
    fn heartbeats_and_restart_requests() {
        let s = sup(0, 0);
        assert_eq!(s.heartbeat(0), 0);
        s.beat(0);
        s.beat(0);
        assert_eq!(s.heartbeat(0), 2);
        assert_eq!(s.heartbeat(1), 0, "heartbeats are per-shard");
        assert!(!s.take_restart_request(0));
        s.request_restart(0);
        assert!(s.take_restart_request(0), "request is delivered once");
        assert!(!s.take_restart_request(0), "and consumed");
    }

    #[test]
    fn health_state_codes_round_trip() {
        for st in [
            HealthState::Healthy,
            HealthState::Degraded,
            HealthState::Restarting,
            HealthState::Unhealthy,
        ] {
            assert_eq!(HealthState::from_code(st as u8), st);
        }
        assert_eq!(HealthState::from_code(250), HealthState::Healthy, "unknown codes degrade");
        assert_eq!(HealthState::Unhealthy.name(), "unhealthy");
    }
}
