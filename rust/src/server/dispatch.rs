//! Sharded dispatch: `ServerConfig.workers` shard threads, each owning
//! its own `LaneStepper` and active lane set, fed by per-shard bounded
//! [`JobQueue`]s. The dispatcher routes each submitted job to the shard
//! with the least *predicted* remaining work — estimated FLOPs of queued
//! plus active lanes, where the active estimate extrapolates the FLOPs
//! each lane has actually executed per completed step (see
//! `Lane::remaining_flops_estimate`) — falling back to lane counts only
//! as a tie-break. Cache schedules and token reduction shift the compute
//! profile per request, so balancing raw lane counts would systematically
//! overload shards whose lanes happen to be cache-heavy.
//!
//! Sharing: the `ScheduleCache` is `Arc<Mutex<_>>`-shared across shards;
//! the model factory is `Arc`-shared and invoked once per shard ON the
//! shard's thread, because PJRT clients (and their device buffers) must
//! not cross threads — weight generation is seed-deterministic, so every
//! shard serves identical weights. In native mode this costs one
//! host-side `WeightBank` copy per shard; in HLO mode per-shard device
//! uploads are required anyway.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{FastCacheConfig, ModelConfig, ServerConfig};
use crate::faults::FaultPlan;
use crate::model::DitModel;
use crate::obs::{FlightRecorder, Registry, ShardMetrics, DEFAULT_TRACE_EVENT_CAP};
use crate::scheduler::ScheduleCache;
use crate::store::WarmStore;

use crate::api::{Event, Outcome, Reject};

use super::queue::{Job, JobQueue, Push};
use super::supervisor::{HealthState, Supervisor};
use super::worker::{shard_loop, ServerReport, ShardReport};

/// Live load signals one shard publishes for the router.
#[derive(Default)]
pub struct ShardLoad {
    /// Predicted FLOPs of jobs routed to this shard but not yet admitted.
    pub queued_flops: AtomicU64,
    /// Predicted remaining FLOPs across the shard's active lanes.
    pub active_flops: AtomicU64,
    /// Active lane count (tie-break when FLOP predictions are equal).
    pub active_lanes: AtomicUsize,
}

impl ShardLoad {
    /// Total predicted outstanding work on this shard.
    pub fn predicted_flops(&self) -> u64 {
        self.queued_flops
            .load(Ordering::Relaxed)
            .saturating_add(self.active_flops.load(Ordering::Relaxed))
    }
}

struct Shard {
    queue: Arc<JobQueue>,
    load: Arc<ShardLoad>,
    handle: JoinHandle<ShardReport>,
    /// Kept so shutdown can still produce this shard's report from its
    /// live metrics if the thread died instead of returning one.
    metrics: Arc<ShardMetrics>,
}

/// The sharded serving core behind `server::Server`.
pub struct Dispatcher {
    shards: Vec<Shard>,
    /// Full-compute FLOPs of one denoise step (layers × block at full
    /// tokens) — the unit queued-job costs are quoted in.
    step_flops: u64,
    /// The cross-request warm-start store shared by every shard (`None`
    /// when warm-start is off). May be caller-owned and outlive this
    /// dispatcher (fleet semantics).
    store: Option<Arc<WarmStore>>,
    started: Instant,
    /// The live telemetry registry: every shard's series plus the net
    /// door's, scrapeable while the server runs. The shutdown report is
    /// the registry's final snapshot.
    registry: Arc<Registry>,
    /// Flight recorder, shared by every shard (`None` unless
    /// `ServerConfig::trace_sample_rate > 0`).
    recorder: Option<Arc<FlightRecorder>>,
    /// Deterministic fault plan parsed from `ServerConfig::fault_plan`
    /// (`None` — and zero overhead — unless one was configured).
    faults: Option<Arc<FaultPlan>>,
    /// The shard supervisor: flap control, poisoned-request blocklist,
    /// heartbeats, and per-shard health. Always present (the health
    /// surface must answer even on an unconfigured server); inert with
    /// all knobs at 0.
    supervisor: Arc<Supervisor>,
    /// The stuck-step watchdog thread (armed by `step_stall_ms > 0`):
    /// stop-sender + join handle, shut down before the shards drain.
    watchdog: Option<(mpsc::Sender<()>, JoinHandle<()>)>,
}

impl Dispatcher {
    /// Spawn the shard threads. The factory runs once per shard, on that
    /// shard's thread (PJRT clients are not shared across threads). The
    /// warm-start store — when present — is `Arc`-shared across shards:
    /// lanes consult it at admission and publish back on retirement.
    pub fn start<F>(
        scfg: &ServerConfig,
        fc: &FastCacheConfig,
        store: Option<Arc<WarmStore>>,
        model_factory: F,
    ) -> Dispatcher
    where
        F: Fn() -> Result<DitModel> + Send + Sync + 'static,
    {
        // Guards against unvalidated configs: at least one shard, and at
        // least one queue slot per shard.
        let workers = scfg.workers.max(1);
        let cap = (scfg.queue_depth / workers).max(1);
        let factory = Arc::new(model_factory);
        let schedules = Arc::new(Mutex::new(ScheduleCache::new()));
        let step_flops = ModelConfig::of(scfg.variant).full_step_flops();
        let recorder = (scfg.trace_sample_rate > 0.0).then(|| {
            Arc::new(FlightRecorder::new(scfg.trace_sample_rate, DEFAULT_TRACE_EVENT_CAP))
        });
        // Parse the fault plan once; an empty plan collapses to `None` so
        // the serve loops carry no fault state at all. A malformed plan is
        // a caller bug — `ServerConfig::validate` rejects it first on
        // every config-driven path.
        let faults = scfg
            .fault_plan
            .as_deref()
            .map(|s| FaultPlan::parse(s).expect("invalid fault plan (ServerConfig::validate catches this)"))
            .filter(|p| !p.is_empty())
            .map(Arc::new);
        let shard_metrics: Vec<Arc<ShardMetrics>> =
            (0..workers).map(|id| Arc::new(ShardMetrics::new(id))).collect();
        let supervisor = Arc::new(Supervisor::new(workers, scfg));
        let registry = Registry::new(shard_metrics.clone(), store.clone())
            .with_supervisor(Arc::clone(&supervisor));
        let registry = Arc::new(match &faults {
            Some(plan) => registry.with_faults(Arc::clone(plan)),
            None => registry,
        });

        let shards: Vec<Shard> = (0..workers)
            .map(|id| {
                let queue = Arc::new(JobQueue::new(cap));
                let load = Arc::new(ShardLoad::default());
                let ctx = super::worker::ShardCtx {
                    id,
                    scfg: scfg.clone(),
                    fc: fc.clone(),
                    queue: Arc::clone(&queue),
                    load: Arc::clone(&load),
                    schedules: Arc::clone(&schedules),
                    warm_store: store.clone(),
                    metrics: Arc::clone(&shard_metrics[id]),
                    recorder: recorder.clone(),
                    faults: faults.clone(),
                    supervisor: Arc::clone(&supervisor),
                };
                let f = Arc::clone(&factory);
                let metrics = Arc::clone(&shard_metrics[id]);
                let handle = std::thread::Builder::new()
                    .name(format!("fastcache-shard-{id}"))
                    .spawn(move || shard_loop(ctx, f.as_ref()))
                    .expect("spawning shard thread");
                Shard { queue, load, handle, metrics }
            })
            .collect();

        let watchdog = (scfg.step_stall_ms > 0).then(|| {
            let (stop_tx, stop_rx) = mpsc::channel::<()>();
            let watch: Vec<WatchedShard> = shards
                .iter()
                .map(|s| WatchedShard {
                    queue: Arc::clone(&s.queue),
                    load: Arc::clone(&s.load),
                    metrics: Arc::clone(&s.metrics),
                })
                .collect();
            let sup = Arc::clone(&supervisor);
            let stall = Duration::from_millis(scfg.step_stall_ms);
            let handle = std::thread::Builder::new()
                .name("fastcache-watchdog".into())
                .spawn(move || watchdog_loop(sup, watch, stall, stop_rx))
                .expect("spawning watchdog thread");
            (stop_tx, handle)
        });

        Dispatcher {
            shards,
            step_flops,
            store,
            started: Instant::now(),
            registry,
            recorder,
            faults,
            supervisor,
            watchdog,
        }
    }

    /// The parsed fault plan, when one is configured (shared with the
    /// net door for socket-reset injection and with the CLI for
    /// counter assertions in chaos runs).
    pub fn fault_plan(&self) -> Option<Arc<FaultPlan>> {
        self.faults.clone()
    }

    /// The warm store attached to this dispatcher, if any.
    pub fn warm_store(&self) -> Option<Arc<WarmStore>> {
        self.store.clone()
    }

    /// The live telemetry registry (scraped by the net door's `Stats`
    /// frame, `--stats-every`, and the CLI).
    pub fn registry(&self) -> Arc<Registry> {
        Arc::clone(&self.registry)
    }

    /// The flight recorder, when tracing is enabled.
    pub fn recorder(&self) -> Option<Arc<FlightRecorder>> {
        self.recorder.clone()
    }

    /// The shard supervisor (health states, blocklist counters) —
    /// shared with the registry, the net door's `Health` frame, and the
    /// CLI.
    pub fn supervisor(&self) -> Arc<Supervisor> {
        Arc::clone(&self.supervisor)
    }

    pub fn workers(&self) -> usize {
        self.shards.len()
    }

    /// Route a job to the least-predicted-load shard, falling back
    /// through heavier shards when queues are full. `Busy` only when
    /// every shard pushed back; `Closed` only when every shard is gone.
    pub fn submit(&self, mut job: Job) -> Result<(), Reject> {
        // Poisoned-request gate: a blocklisted req_id is refused BEFORE
        // it takes a queue slot. One gate covers both doors — the net
        // front door funnels through this same submit path. The
        // rejection still counts against the SLA when the request
        // carried a deadline (see `ServerReport::deadline_hit_rate`).
        if self.supervisor.is_poisoned(job.req.id) {
            self.supervisor.note_poisoned_rejection(job.req.deadline_ms.is_some());
            return Err(Reject::poisoned(
                job.req.id,
                format!(
                    "request {} blocklisted after {} typed quarantines",
                    job.req.id,
                    self.supervisor.poison_after()
                ),
            ));
        }
        job.cost = job.req.steps as u64 * self.step_flops;
        let mut order: Vec<usize> = (0..self.shards.len()).collect();
        order.sort_by_key(|&i| {
            let s = &self.shards[i];
            (s.load.predicted_flops(), s.load.active_lanes.load(Ordering::Relaxed), i)
        });

        let mut saw_full = false;
        for &i in &order {
            let shard = &self.shards[i];
            // Account the queued cost BEFORE the push so a concurrent
            // submitter routing in parallel sees this job; roll back on
            // rejection.
            shard.load.queued_flops.fetch_add(job.cost, Ordering::Relaxed);
            match shard.queue.push(job) {
                Push::Accepted => return Ok(()),
                Push::Full(j) => {
                    shard.load.queued_flops.fetch_sub(j.cost, Ordering::Relaxed);
                    saw_full = true;
                    job = *j;
                }
                Push::Closed(j) => {
                    shard.load.queued_flops.fetch_sub(j.cost, Ordering::Relaxed);
                    job = *j;
                }
            }
        }
        if saw_full {
            Err(Reject::busy(job.req.id, "every shard queue at capacity"))
        } else {
            Err(Reject::closed(job.req.id, "server shutting down"))
        }
    }

    /// Close every shard queue, wait for the shards to drain, and merge
    /// their reports into one aggregate with a per-shard breakdown (plus
    /// the warm store's counters, when one was attached).
    pub fn shutdown(self) -> ServerReport {
        // Stop the watchdog FIRST: a slow graceful drain must not be
        // mistaken for a stall and have its queues shed.
        if let Some((stop_tx, handle)) = self.watchdog {
            drop(stop_tx);
            let _ = handle.join();
        }
        for shard in &self.shards {
            shard.queue.close();
        }
        // A shard thread that died without returning a report (a panic
        // that escaped fault containment — e.g. model-load failure) must
        // not take shutdown down with it: its queue's DrainOnExit guard
        // already answered its submitters, so fall back to the thread's
        // last live metrics and keep merging.
        let reports: Vec<ShardReport> = self
            .shards
            .into_iter()
            .map(|s| match s.handle.join() {
                Ok(report) => report,
                Err(_) => {
                    let report = s.metrics.snapshot();
                    eprintln!(
                        "shard {}: thread died outside fault containment; \
                         reporting its last metrics snapshot",
                        report.shard
                    );
                    report
                }
            })
            .collect();
        let store_stats = self.store.as_ref().map(|s| s.stats());
        let mut report =
            ServerReport::merge(reports, self.started.elapsed().as_secs_f64(), store_stats);
        // Admission-time rejections never reach a shard, so the merge
        // can't see them: fold the supervisor's counters in here.
        report.poisoned_rejections = self.supervisor.poisoned_rejections();
        report.poisoned_sheds = self.supervisor.poisoned_sheds();
        report.blocklisted = self.supervisor.blocklisted();
        report
    }
}

/// The per-shard handles the watchdog needs to shed a wedged shard's
/// queue (it never touches the stepper — only the shard thread owns
/// that).
struct WatchedShard {
    queue: Arc<JobQueue>,
    load: Arc<ShardLoad>,
    metrics: Arc<ShardMetrics>,
}

/// Stuck-step watchdog: poll the per-shard heartbeats a few times per
/// stall budget. A heartbeat that stops advancing WHILE LANES ARE ACTIVE
/// for longer than `stall` means a step is wedged (a panic would have
/// been caught and quarantined — this is the no-unwind failure shape):
/// mark the shard [`HealthState::Unhealthy`], shed its queue honestly
/// (deadline sheds count as SLA misses), and request a supervised
/// restart, which the shard thread performs when the wedged step
/// finally returns. Exits when the stop channel drops (shutdown).
fn watchdog_loop(
    sup: Arc<Supervisor>,
    watch: Vec<WatchedShard>,
    stall: Duration,
    stop_rx: mpsc::Receiver<()>,
) {
    struct Seen {
        beat: u64,
        since: Instant,
        flagged: bool,
    }
    let now = Instant::now();
    let mut seen: Vec<Seen> = watch
        .iter()
        .enumerate()
        .map(|(i, _)| Seen { beat: sup.heartbeat(i), since: now, flagged: false })
        .collect();
    let tick = (stall / 4).max(Duration::from_millis(10));
    loop {
        match stop_rx.recv_timeout(tick) {
            Ok(()) | Err(mpsc::RecvTimeoutError::Disconnected) => return,
            Err(mpsc::RecvTimeoutError::Timeout) => {}
        }
        let now = Instant::now();
        for (i, w) in watch.iter().enumerate() {
            let beat = sup.heartbeat(i);
            let s = &mut seen[i];
            // Progress, or nothing in flight: the shard is not stuck.
            // (An idle shard parks in pop_blocking without beating, so
            // activity — not the heartbeat alone — arms the timer.)
            if beat != s.beat || w.load.active_lanes.load(Ordering::Relaxed) == 0 {
                s.beat = beat;
                s.since = now;
                s.flagged = false;
                continue;
            }
            if s.flagged || now.duration_since(s.since) < stall {
                continue;
            }
            s.flagged = true;
            sup.set_state(i, HealthState::Unhealthy);
            sup.request_restart(i);
            // Shed the wedged shard's queue honestly: every shed is
            // counted, answered, and (when deadline-tagged) an SLA miss.
            // Work already routed here would otherwise wait behind a
            // stall of unknown length.
            while let Some(job) = w.queue.try_pop() {
                w.load.queued_flops.fetch_sub(job.cost, Ordering::Relaxed);
                w.metrics.watchdog_sheds.inc();
                if job.req.deadline_ms.is_some() {
                    w.metrics.deadline_sheds.inc();
                }
                let rej = Reject::internal(
                    job.req.id,
                    format!(
                        "shard {i} step heartbeat stalled > {} ms; queue shed by watchdog",
                        stall.as_millis()
                    ),
                );
                let _ = job.resp.send(Event::Done(Outcome::Rejected(rej)));
            }
        }
    }
}
